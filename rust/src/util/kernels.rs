//! Runtime-dispatched SIMD kernel layer — the single compute substrate
//! under every inner-loop operation of Algorithm 1.
//!
//! Three dispatch arms:
//!
//!   * `scalar` — the portable baseline.  Bit-identical to the
//!     pre-kernel-layer code (`dot` keeps the 4-lane unrolled
//!     reduction, `axpy` the plain elementwise update, `matmul`/`syrk`
//!     the same per-element accumulation order), so masks and losses
//!     are unchanged on every platform.
//!   * `simd` — AVX2/FMA via `std::arch`, available on x86-64 hosts
//!     that report both features at runtime
//!     (`is_x86_feature_detected!`).
//!   * `avx512` — 512-bit lanes for `dot`, `axpy`, the matmul/syrk
//!     microkernel and the gathered pair scan, on hosts that
//!     additionally report `avx512f`.  Ops without a dedicated
//!     512-bit body (packed `pair_scan`, `axpy_dot`) run their AVX2
//!     sibling — the engine's hot path is the gather variant, so the
//!     packed scan stays a test/bench oracle.
//!
//! The active arm is chosen once per process through a `OnceLock`:
//! `--kernels=scalar|simd|avx512|auto` (CLI) or the
//! `SPARSESWAPS_KERNELS` environment variable override
//! auto-detection; parity tests and benches bypass the global and
//! call the `*_arm` variants directly.
//!
//! Determinism guarantees (relied on by the property tests and the
//! engine parity oracle):
//!
//!   * every kernel is deterministic for a fixed arm and input;
//!   * `axpy` and `axpy_dot`'s update are elementwise mul+add in ALL
//!     arms (no FMA contraction), so the Eq.-6 correlation state — and
//!     therefore every swap decision and mask — is bit-identical
//!     across arms;
//!   * `pair_scan` / `pair_scan_gather` evaluate the separable Eq.-5
//!     delta with the exact scalar rounding sequence in every arm and
//!     resolve argmin ties by first (lowest) index, matching the
//!     scalar loop's strict `dl < best` first-wins semantics — lane
//!     width (4 on AVX2, 8 on AVX-512) never changes the winner;
//!   * `dot`, `matmul` and `syrk` may use FMA and a different
//!     reduction shape on the wide arms; results agree with `scalar`
//!     to relative 1e-4 on realistic inputs (property-tested);
//!   * `pair_scan_f32` trades the exact-f64 accumulation for f32 and
//!     is therefore NOT on the mask-deciding path — the f64 scan
//!     stays wired as its parity oracle in the tests and the bench
//!     gate, and the engine keeps f64.

use std::sync::OnceLock;

use crate::util::tensor::Matrix;

/// A dispatch arm of the kernel layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    Scalar,
    Simd,
    Avx512,
}

impl Arm {
    pub fn name(&self) -> &'static str {
        match self {
            Arm::Scalar => "scalar",
            Arm::Simd => "simd",
            Arm::Avx512 => "avx512",
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub fn simd_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn simd_available() -> bool {
    false
}

/// The avx512 arm keeps AVX2/FMA as its fallback tier for ops without
/// a 512-bit body, so it requires the full `simd` feature set too.
#[cfg(target_arch = "x86_64")]
pub fn avx512_available() -> bool {
    simd_available() && is_x86_feature_detected!("avx512f")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn avx512_available() -> bool {
    false
}

/// Best (widest) arm this host supports.
pub fn detect() -> Arm {
    if avx512_available() {
        Arm::Avx512
    } else if simd_available() {
        Arm::Simd
    } else {
        Arm::Scalar
    }
}

/// Every arm usable on this host (scalar always; wider arms when
/// detected).  Parity tests and benches sweep this list.
pub fn arms() -> Vec<Arm> {
    let mut out = vec![Arm::Scalar];
    if simd_available() {
        out.push(Arm::Simd);
    }
    if avx512_available() {
        out.push(Arm::Avx512);
    }
    out
}

/// Downgrade `arm` to the widest tier this host actually supports —
/// the resolved value is safe to hand to the unchecked dispatchers
/// ([`fma_axpy_inner`] and the panel kernels).
fn resolve(arm: Arm) -> Arm {
    match arm {
        Arm::Avx512 if avx512_available() => Arm::Avx512,
        Arm::Scalar => Arm::Scalar,
        _ if simd_available() => Arm::Simd,
        _ => Arm::Scalar,
    }
}

static ACTIVE: OnceLock<Arm> = OnceLock::new();

/// The process-wide arm, selected once: `select()` wins if called
/// before first use, then `SPARSESWAPS_KERNELS=scalar|simd|avx512`,
/// then runtime detection.
pub fn active() -> Arm {
    *ACTIVE.get_or_init(|| match std::env::var("SPARSESWAPS_KERNELS") {
        Ok(v) if v == "scalar" => Arm::Scalar,
        Ok(v) if v == "simd" && simd_available() => Arm::Simd,
        Ok(v) if v == "avx512" && avx512_available() => Arm::Avx512,
        _ => detect(),
    })
}

/// Lock the process-wide arm from a CLI flag (`--kernels=...`).
/// `auto` defers to [`active`] (so the `SPARSESWAPS_KERNELS` env
/// override still applies); explicit names lock the arm.  Errors on
/// unknown names, on `simd` when the host lacks AVX2/FMA, and when a
/// *different* arm was already locked in.
pub fn select(name: &str) -> Result<Arm, String> {
    let want = match name {
        // Don't lock: let the env override / detection decide lazily.
        "" | "auto" => return Ok(active()),
        "scalar" => Arm::Scalar,
        "simd" => {
            if !simd_available() {
                return Err("SIMD kernels unavailable on this host \
                            (needs x86-64 with AVX2 and FMA)"
                    .into());
            }
            Arm::Simd
        }
        "avx512" => {
            if !avx512_available() {
                return Err("AVX-512 kernels unavailable on this host \
                            (needs x86-64 with AVX2, FMA and AVX512F)"
                    .into());
            }
            Arm::Avx512
        }
        other => {
            return Err(format!(
                "unknown kernel arm {other:?} \
                 (want auto|scalar|simd|avx512)"
            ))
        }
    };
    if ACTIVE.set(want).is_err() {
        let cur = *ACTIVE.get().expect("arm initialised");
        if cur != want {
            return Err(format!(
                "kernel arm already locked to {} for this process",
                cur.name()
            ));
        }
    }
    Ok(want)
}

// --- public ops (global-arm wrappers + explicit-arm variants) ---------------

/// Dot product of two equally-sized f32 slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_arm(active(), a, b)
}

pub fn dot_arm(arm: Arm, a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    match resolve(arm) {
        // SAFETY: feature presence verified by `resolve`.
        Arm::Avx512 => return unsafe { avx512::dot(a, b) },
        Arm::Simd => return unsafe { avx2::dot(a, b) },
        Arm::Scalar => {}
    }
    let _ = arm;
    scalar::dot(a, b)
}

/// y += alpha * x (elementwise; bit-identical across arms).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_arm(active(), alpha, x, y)
}

pub fn axpy_arm(arm: Arm, alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    match resolve(arm) {
        // SAFETY: feature presence verified by `resolve`.
        Arm::Avx512 => return unsafe { avx512::axpy(alpha, x, y) },
        Arm::Simd => return unsafe { avx2::axpy(alpha, x, y) },
        Arm::Scalar => {}
    }
    let _ = arm;
    scalar::axpy(alpha, x, y)
}

/// Fused update + readback: `y += alpha * x`, returns `x . y_updated`
/// in one pass over the operands.  The update half is bit-identical
/// across arms (mul+add, like [`axpy`]); the returned dot may differ
/// in reduction order on the `simd` arm.
///
/// Part of the kernel API surface (bench + property-tested) for
/// fused update-then-readback loops; the refinement path currently
/// keeps its loss accumulation in f64 and so uses plain [`axpy`] —
/// wire this in wherever an f32 readback of the updated vector is
/// acceptable.
#[inline]
pub fn axpy_dot(alpha: f32, x: &[f32], y: &mut [f32]) -> f32 {
    axpy_dot_arm(active(), alpha, x, y)
}

pub fn axpy_dot_arm(arm: Arm, alpha: f32, x: &[f32], y: &mut [f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if resolve(arm) != Arm::Scalar {
        // No dedicated 512-bit body: the avx512 arm runs its AVX2
        // fallback tier here (update half stays elementwise mul+add,
        // so bit-identity across arms is preserved either way).
        // SAFETY: AVX2+FMA presence verified by `resolve`.
        return unsafe { avx2::axpy_dot(alpha, x, y) };
    }
    let _ = arm;
    scalar::axpy_dot(alpha, x, y)
}

/// Separable Eq.-5 pair scan over packed per-pruned-index terms:
/// `dl[i] = au + b[i] - wu2 * wp[i] * gp[i]` (all f64), returning the
/// first index achieving the strict minimum below `best`, or `None`
/// when nothing improves on `best`.  Both arms compute each `dl[i]`
/// with the identical rounding sequence, so the selected pair is
/// bit-identical across arms.
pub fn pair_scan_arm(
    arm: Arm,
    au: f64,
    wu2: f64,
    b: &[f64],
    wp: &[f64],
    gp: &[f64],
    best: f64,
) -> Option<(f64, usize)> {
    #[cfg(target_arch = "x86_64")]
    if resolve(arm) != Arm::Scalar {
        // The engine's hot path is the gather variant; the packed scan
        // keeps a single AVX2 body that the avx512 arm reuses (same
        // bit-exact result at any lane width).
        // SAFETY: AVX2 presence verified by `resolve`.
        return unsafe { avx2::pair_scan(au, wu2, b, wp, gp, best) };
    }
    let _ = arm;
    scalar::pair_scan(au, wu2, b, wp, gp, best)
}

/// [`pair_scan_arm`] with a fused gather: instead of consuming a
/// packed f64 `gp` buffer, reads `G_up` straight out of the f32 Gram
/// row at the pruned indices (`gp[i] = g_row[pruned[i]] as f64`).
/// The f32 -> f64 widening is exact, so every `dl` rounds identically
/// to the packed scan and the selected pair is bit-identical across
/// all three paths (packed scalar, packed SIMD, gathered).  The simd
/// arm uses AVX2 `vgatherqps`, which is what lets the engine drop the
/// per-kept-index packing pass entirely.
///
/// Requires every `pruned[i] < g_row.len()` (mask indices of one
/// row).
pub fn pair_scan_gather_arm(
    arm: Arm,
    au: f64,
    wu2: f64,
    b: &[f64],
    wp: &[f64],
    g_row: &[f32],
    pruned: &[usize],
    best: f64,
) -> Option<(f64, usize)> {
    debug_assert_eq!(b.len(), wp.len());
    debug_assert_eq!(b.len(), pruned.len());
    debug_assert!(pruned.iter().all(|&p| p < g_row.len()));
    #[cfg(target_arch = "x86_64")]
    match resolve(arm) {
        // SAFETY: feature presence verified by `resolve`; the caller
        // guarantees every gathered index is in bounds.
        Arm::Avx512 => {
            return unsafe {
                avx512::pair_scan_gather(au, wu2, b, wp, g_row, pruned,
                                         best)
            }
        }
        Arm::Simd => {
            return unsafe {
                avx2::pair_scan_gather(au, wu2, b, wp, g_row, pruned,
                                       best)
            }
        }
        Arm::Scalar => {}
    }
    let _ = arm;
    scalar::pair_scan_gather(au, wu2, b, wp, g_row, pruned, best)
}

/// f32-accumulation sibling of the Eq.-5 pair scan: identical
/// formula, ties and first-wins semantics, but every term and the
/// running best stay in f32.  One f32 FLOP per lane instead of f64
/// doubles the lanes per vector (16 on AVX-512) — but f32 rounding
/// can pick a different winner when two candidates are closer than
/// ~1e-7 relative, so this is NOT used on the mask-deciding path: the
/// engine keeps the exact-f64 scan, which also serves as this
/// function's parity oracle in the property tests and the bench gate.
#[inline]
pub fn pair_scan_f32(
    au: f32,
    wu2: f32,
    b: &[f32],
    wp: &[f32],
    gp: &[f32],
    best: f32,
) -> Option<(f32, usize)> {
    pair_scan_f32_arm(active(), au, wu2, b, wp, gp, best)
}

/// [`pair_scan_f32`] on an explicit arm.  The scalar and avx512
/// bodies compute each `dl` with the identical f32 rounding sequence,
/// so the selected pair is bit-identical across arms; the simd arm
/// has no dedicated body and runs the scalar one.
pub fn pair_scan_f32_arm(
    arm: Arm,
    au: f32,
    wu2: f32,
    b: &[f32],
    wp: &[f32],
    gp: &[f32],
    best: f32,
) -> Option<(f32, usize)> {
    debug_assert_eq!(b.len(), wp.len());
    debug_assert_eq!(b.len(), gp.len());
    #[cfg(target_arch = "x86_64")]
    if resolve(arm) == Arm::Avx512 {
        // SAFETY: AVX512F presence verified by `resolve`.
        return unsafe {
            avx512::pair_scan_f32(au, wu2, b, wp, gp, best)
        };
    }
    let _ = arm;
    scalar::pair_scan_f32(au, wu2, b, wp, gp, best)
}

/// Cache-blocked matrix multiply `A * B` with packed B panels.
/// The scalar arm reproduces the historic ikj loop bit-for-bit (same
/// per-element accumulation order over k, same skip of zero A
/// entries); the simd arm runs the inner microkernel with FMA.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_arm(active(), a, b)
}

/// [`matmul`] parallelised over output-row panels — the same scheme
/// [`syrk_arm`] uses.  Each worker runs the full blocked loop over
/// its row range with a private B pack buffer, so every output
/// element's accumulation order is unchanged and results are
/// bit-identical for any thread count (per arm).
pub fn matmul_par(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    matmul_arm_par(active(), a, b, threads)
}

/// k-panel height of the blocked matmul/packing loop.
const MATMUL_KC: usize = 128;
/// j-panel width of the blocked matmul/packing loop.
const MATMUL_NC: usize = 512;

pub fn matmul_arm(arm: Arm, a: &Matrix, b: &Matrix) -> Matrix {
    matmul_arm_par(arm, a, b, 1)
}

pub fn matmul_arm_par(arm: Arm, a: &Matrix, b: &Matrix, threads: usize)
    -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (n, k, m) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(n, m);
    if n == 0 || k == 0 || m == 0 {
        return out;
    }
    let arm = resolve(arm);
    let n_threads = threads.max(1).min(n);
    if n_threads <= 1 {
        matmul_panel(arm, a, b, &mut out.data, 0, n);
        return out;
    }
    let chunk = n.div_ceil(n_threads);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(n_threads);
    let mut rest = out.data.as_mut_slice();
    let mut i0 = 0usize;
    while i0 < n {
        let rows_here = chunk.min(n - i0);
        let (panel, tail) = rest.split_at_mut(rows_here * m);
        rest = tail;
        let lo = i0;
        jobs.push(Box::new(move || {
            matmul_panel(arm, a, b, panel, lo, lo + rows_here)
        }));
        i0 += rows_here;
    }
    crate::util::threadpool::global().run_scoped(jobs);
    out
}

/// Compute output rows [i0, i1) into `panel` (the corresponding
/// contiguous row slice of C) with a private B pack buffer.  `arm`
/// must already be resolved.
fn matmul_panel(
    arm: Arm,
    a: &Matrix,
    b: &Matrix,
    panel: &mut [f32],
    i0: usize,
    i1: usize,
) {
    let (k, m) = (a.cols, b.cols);
    let mut pack = vec![0.0f32; MATMUL_KC.min(k) * MATMUL_NC.min(m)];
    let mut jc = 0;
    while jc < m {
        let jw = MATMUL_NC.min(m - jc);
        let mut kc = 0;
        while kc < k {
            let kw = MATMUL_KC.min(k - kc);
            // Pack the B panel [kc..kc+kw) x [jc..jc+jw) contiguously
            // so the microkernel streams one cache-resident buffer.
            for kk in 0..kw {
                let src = (kc + kk) * m + jc;
                pack[kk * jw..kk * jw + jw]
                    .copy_from_slice(&b.data[src..src + jw]);
            }
            for i in i0..i1 {
                let arow = &a.data[i * k + kc..i * k + kc + kw];
                let crow = &mut panel[(i - i0) * m + jc
                                      ..(i - i0) * m + jc + jw];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &pack[kk * jw..kk * jw + jw];
                    fma_axpy_inner(arm, av, brow, crow);
                }
            }
            kc += kw;
        }
        jc += jw;
    }
}

/// Inner microkernel of matmul/syrk: `y += a * x`, FMA on the wide
/// arms.  `arm` must already be resolved ([`resolve`]) — the wide
/// branches dispatch without re-checking feature presence.
#[inline]
fn fma_axpy_inner(arm: Arm, alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    match arm {
        // SAFETY: `resolve` only yields a wide arm after detection.
        Arm::Avx512 => {
            unsafe { avx512::fma_axpy(alpha, x, y) };
            return;
        }
        Arm::Simd => {
            unsafe { avx2::fma_axpy(alpha, x, y) };
            return;
        }
        Arm::Scalar => {}
    }
    let _ = arm;
    scalar::axpy(alpha, x, y);
}

/// Symmetric rank-k update `G += X^T X` for an activation block X
/// ([t, d] row-major): computes only the upper triangle (halving the
/// FLOPs) and mirrors it, parallelised over row panels on the in-repo
/// thread pool.
///
/// Contract: `G` must be exactly symmetric on entry (zeros, or the
/// result of previous `syrk` / `gram_accumulate` calls — those are
/// exactly symmetric because f32 multiplication commutes).  The
/// scalar arm is bit-identical to the historic dense accumulation for
/// any thread count: each element's contributions are added in
/// ascending-`t` order regardless of panel assignment.
pub fn syrk_arm(arm: Arm, g: &mut Matrix, x: &Matrix, threads: usize) {
    assert_eq!(g.rows, x.cols, "syrk shape mismatch");
    assert_eq!(g.cols, x.cols, "syrk shape mismatch");
    let d = x.cols;
    if d == 0 {
        return;
    }
    let arm = resolve(arm);
    let n_threads = threads.max(1).min(d);
    if n_threads <= 1 {
        syrk_panel(arm, &mut g.data, 0, d, d, x);
    } else {
        let chunk = d.div_ceil(n_threads);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(n_threads);
        let mut rest = g.data.as_mut_slice();
        let mut i0 = 0usize;
        while i0 < d {
            let rows_here = chunk.min(d - i0);
            let (panel, tail) = rest.split_at_mut(rows_here * d);
            rest = tail;
            let lo = i0;
            jobs.push(Box::new(move || {
                syrk_panel(arm, panel, lo, lo + rows_here, d, x)
            }));
            i0 += rows_here;
        }
        crate::util::threadpool::global().run_scoped(jobs);
    }
    // Mirror the accumulated upper triangle into the lower one.
    for i in 0..d {
        for j in i + 1..d {
            g.data[j * d + i] = g.data[i * d + j];
        }
    }
}

/// Accumulate rows [i0, i1) of the upper triangle into `panel` (the
/// corresponding contiguous row slice of G).  `arm` must already be
/// resolved.
fn syrk_panel(
    arm: Arm,
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    d: usize,
    x: &Matrix,
) {
    for i in i0..i1 {
        let grow = &mut panel[(i - i0) * d..(i - i0) * d + d];
        for t in 0..x.rows {
            let xr = x.row(t);
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            fma_axpy_inner(arm, xi, &xr[i..], &mut grow[i..]);
        }
    }
}

// --- scalar arm -------------------------------------------------------------

mod scalar {
    /// 4-lane unrolled accumulation — the historic `util::tensor::dot`,
    /// kept verbatim so the scalar arm stays bit-identical to the
    /// pre-kernel-layer code.
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 4];
        let chunks = a.len() / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc[0] += a[i] * b[i];
            acc[1] += a[i + 1] * b[i + 1];
            acc[2] += a[i + 2] * b[i + 2];
            acc[3] += a[i + 3] * b[i + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    pub fn axpy_dot(alpha: f32, x: &[f32], y: &mut [f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = [0.0f32; 4];
        let chunks = x.len() / 4;
        for c in 0..chunks {
            let i = c * 4;
            y[i] += alpha * x[i];
            acc[0] += x[i] * y[i];
            y[i + 1] += alpha * x[i + 1];
            acc[1] += x[i + 1] * y[i + 1];
            y[i + 2] += alpha * x[i + 2];
            acc[2] += x[i + 2] * y[i + 2];
            y[i + 3] += alpha * x[i + 3];
            acc[3] += x[i + 3] * y[i + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..x.len() {
            y[i] += alpha * x[i];
            s += x[i] * y[i];
        }
        s
    }

    /// The historic inner pair loop, verbatim: strict `<` keeps the
    /// first index achieving the minimum.
    pub fn pair_scan(
        au: f64,
        wu2: f64,
        b: &[f64],
        wp: &[f64],
        gp: &[f64],
        best: f64,
    ) -> Option<(f64, usize)> {
        debug_assert_eq!(b.len(), wp.len());
        debug_assert_eq!(b.len(), gp.len());
        let mut cur: Option<(f64, usize)> = None;
        let mut best_dl = best;
        for i in 0..b.len() {
            let dl = au + b[i] - wu2 * wp[i] * gp[i];
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
        }
        cur
    }

    /// f32-accumulation scan: same shape as [`pair_scan`], every term
    /// in f32.  The avx512 body computes per-element identically, so
    /// results are bit-identical across f32 arms — but NOT to the f64
    /// scan, which is the oracle it is tested against.
    pub fn pair_scan_f32(
        au: f32,
        wu2: f32,
        b: &[f32],
        wp: &[f32],
        gp: &[f32],
        best: f32,
    ) -> Option<(f32, usize)> {
        let mut cur: Option<(f32, usize)> = None;
        let mut best_dl = best;
        for i in 0..b.len() {
            let dl = au + b[i] - wu2 * wp[i] * gp[i];
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
        }
        cur
    }

    /// [`pair_scan`] reading `G_up` at the pruned indices instead of
    /// from a packed buffer.  `g_row[p] as f64` is exact, so the
    /// rounding sequence — and therefore the winner — is identical.
    pub fn pair_scan_gather(
        au: f64,
        wu2: f64,
        b: &[f64],
        wp: &[f64],
        g_row: &[f32],
        pruned: &[usize],
        best: f64,
    ) -> Option<(f64, usize)> {
        let mut cur: Option<(f64, usize)> = None;
        let mut best_dl = best;
        for i in 0..b.len() {
            let gp = g_row[pruned[i]] as f64;
            let dl = au + b[i] - wu2 * wp[i] * gp;
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
        }
        cur
    }
}

// --- AVX2/FMA arm -----------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Deterministic lane reduction: spill and sum in fixed order.
    #[inline]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        let mut s = 0.0f32;
        for l in lanes {
            s += l;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
                acc0,
            );
            i += 8;
        }
        let mut s = hsum_ps(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Elementwise mul+add — deliberately NOT fused, so every element
    /// rounds exactly like the scalar arm and the Eq.-6 correlation
    /// state stays bit-identical across arms.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(px.add(i)));
            let sum = _mm256_add_ps(_mm256_loadu_ps(py.add(i)), prod);
            _mm256_storeu_ps(py.add(i), sum);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// Fused microkernel for matmul/syrk accumulation (FMA allowed).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn fma_axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let acc = _mm256_fmadd_ps(
                av,
                _mm256_loadu_ps(px.add(i)),
                _mm256_loadu_ps(py.add(i)),
            );
            _mm256_storeu_ps(py.add(i), acc);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy_dot(alpha: f32, x: &[f32], y: &mut [f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm256_set1_ps(alpha);
        let mut acc = _mm256_setzero_ps();
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(px.add(i));
            // Update half: mul+add, bit-identical to the scalar arm.
            let yv = _mm256_add_ps(
                _mm256_loadu_ps(py.add(i)),
                _mm256_mul_ps(av, xv),
            );
            _mm256_storeu_ps(py.add(i), yv);
            acc = _mm256_fmadd_ps(xv, yv, acc);
            i += 8;
        }
        let mut s = hsum_ps(acc);
        while i < n {
            y[i] += alpha * x[i];
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// Vectorised Eq.-5 scan: 4 f64 lanes, per-lane running best with
    /// first-wins semantics, then a lexicographic (dl, index) lane
    /// reduction.  Each `dl` is computed with the exact scalar rounding
    /// sequence (no FMA), so the result is bit-identical to
    /// `scalar::pair_scan`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pair_scan(
        au: f64,
        wu2: f64,
        b: &[f64],
        wp: &[f64],
        gp: &[f64],
        best: f64,
    ) -> Option<(f64, usize)> {
        debug_assert_eq!(b.len(), wp.len());
        debug_assert_eq!(b.len(), gp.len());
        let n = b.len();
        let mut i = 0usize;
        let mut cur: Option<(f64, usize)> = None;
        if n >= 8 {
            let au_v = _mm256_set1_pd(au);
            let wu2_v = _mm256_set1_pd(wu2);
            let mut best_v = _mm256_set1_pd(best);
            let mut idx_v = _mm256_set1_pd(-1.0);
            let mut lane = _mm256_setr_pd(0.0, 1.0, 2.0, 3.0);
            let four = _mm256_set1_pd(4.0);
            while i + 4 <= n {
                let bv = _mm256_loadu_pd(b.as_ptr().add(i));
                let wv = _mm256_loadu_pd(wp.as_ptr().add(i));
                let gv = _mm256_loadu_pd(gp.as_ptr().add(i));
                // (au + b) - ((wu2 * wp) * gp): scalar rounding order.
                let dl = _mm256_sub_pd(
                    _mm256_add_pd(au_v, bv),
                    _mm256_mul_pd(_mm256_mul_pd(wu2_v, wv), gv),
                );
                let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(dl, best_v);
                best_v = _mm256_blendv_pd(best_v, dl, lt);
                idx_v = _mm256_blendv_pd(idx_v, lane, lt);
                lane = _mm256_add_pd(lane, four);
                i += 4;
            }
            let mut bests = [0.0f64; 4];
            let mut idxs = [0.0f64; 4];
            _mm256_storeu_pd(bests.as_mut_ptr(), best_v);
            _mm256_storeu_pd(idxs.as_mut_ptr(), idx_v);
            // Lane l's best index is the first in that lane's
            // subsequence; the lexicographic (dl, idx) reduction then
            // recovers the global first-wins winner.
            for l in 0..4 {
                if idxs[l] < 0.0 {
                    continue;
                }
                let (dl, kp) = (bests[l], idxs[l] as usize);
                cur = match cur {
                    Some((cd, ck))
                        if !(dl < cd || (dl == cd && kp < ck)) =>
                    {
                        Some((cd, ck))
                    }
                    _ => Some((dl, kp)),
                };
            }
        }
        let mut best_dl = match cur {
            Some((cd, _)) => cd,
            None => best,
        };
        while i < n {
            let dl = au + b[i] - wu2 * wp[i] * gp[i];
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
            i += 1;
        }
        cur
    }

    /// [`pair_scan`] with the `G_up` operand gathered from the f32
    /// Gram row at the pruned indices (`vgatherqps`: 4 x i64 indices
    /// loaded straight from the `&[usize]` partition, 4 gathered f32
    /// lanes widened to f64).  The widening is exact and each `dl`
    /// keeps the scalar rounding sequence, so the result is
    /// bit-identical to `scalar::pair_scan_gather` — and to the packed
    /// scans.
    ///
    /// SAFETY contract (caller): every `pruned[i] < g_row.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pair_scan_gather(
        au: f64,
        wu2: f64,
        b: &[f64],
        wp: &[f64],
        g_row: &[f32],
        pruned: &[usize],
        best: f64,
    ) -> Option<(f64, usize)> {
        debug_assert_eq!(b.len(), wp.len());
        debug_assert_eq!(b.len(), pruned.len());
        let n = b.len();
        let mut i = 0usize;
        let mut cur: Option<(f64, usize)> = None;
        if n >= 8 {
            let au_v = _mm256_set1_pd(au);
            let wu2_v = _mm256_set1_pd(wu2);
            let mut best_v = _mm256_set1_pd(best);
            let mut idx_v = _mm256_set1_pd(-1.0);
            let mut lane = _mm256_setr_pd(0.0, 1.0, 2.0, 3.0);
            let four = _mm256_set1_pd(4.0);
            while i + 4 <= n {
                let bv = _mm256_loadu_pd(b.as_ptr().add(i));
                let wv = _mm256_loadu_pd(wp.as_ptr().add(i));
                // usize is 64-bit on x86-64, so four pruned indices
                // load directly as the i64 gather offsets.
                let off = _mm256_loadu_si256(
                    pruned.as_ptr().add(i) as *const __m256i);
                let g32 = _mm256_i64gather_ps::<4>(g_row.as_ptr(), off);
                let gv = _mm256_cvtps_pd(g32);
                // (au + b) - ((wu2 * wp) * gp): scalar rounding order.
                let dl = _mm256_sub_pd(
                    _mm256_add_pd(au_v, bv),
                    _mm256_mul_pd(_mm256_mul_pd(wu2_v, wv), gv),
                );
                let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(dl, best_v);
                best_v = _mm256_blendv_pd(best_v, dl, lt);
                idx_v = _mm256_blendv_pd(idx_v, lane, lt);
                lane = _mm256_add_pd(lane, four);
                i += 4;
            }
            let mut bests = [0.0f64; 4];
            let mut idxs = [0.0f64; 4];
            _mm256_storeu_pd(bests.as_mut_ptr(), best_v);
            _mm256_storeu_pd(idxs.as_mut_ptr(), idx_v);
            for l in 0..4 {
                if idxs[l] < 0.0 {
                    continue;
                }
                let (dl, kp) = (bests[l], idxs[l] as usize);
                cur = match cur {
                    Some((cd, ck))
                        if !(dl < cd || (dl == cd && kp < ck)) =>
                    {
                        Some((cd, ck))
                    }
                    _ => Some((dl, kp)),
                };
            }
        }
        let mut best_dl = match cur {
            Some((cd, _)) => cd,
            None => best,
        };
        while i < n {
            let gp = g_row[pruned[i]] as f64;
            let dl = au + b[i] - wu2 * wp[i] * gp;
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
            i += 1;
        }
        cur
    }
}

// --- AVX-512 arm ------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx512 {
    use std::arch::x86_64::*;

    /// Deterministic lane reduction: spill and sum in fixed order.
    #[inline]
    unsafe fn hsum_ps(v: __m512) -> f32 {
        let mut lanes = [0.0f32; 16];
        _mm512_storeu_ps(lanes.as_mut_ptr(), v);
        let mut s = 0.0f32;
        for l in lanes {
            s += l;
        }
        s
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm512_setzero_ps();
        let mut acc1 = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i)),
                _mm512_loadu_ps(pb.add(i)),
                acc0,
            );
            acc1 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i + 16)),
                _mm512_loadu_ps(pb.add(i + 16)),
                acc1,
            );
            i += 32;
        }
        while i + 16 <= n {
            acc0 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i)),
                _mm512_loadu_ps(pb.add(i)),
                acc0,
            );
            i += 16;
        }
        let mut s = hsum_ps(_mm512_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Elementwise mul+add — deliberately NOT fused, so every element
    /// rounds exactly like the scalar and AVX2 arms and the Eq.-6
    /// correlation state stays bit-identical across all three.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm512_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let prod = _mm512_mul_ps(av, _mm512_loadu_ps(px.add(i)));
            let sum = _mm512_add_ps(_mm512_loadu_ps(py.add(i)), prod);
            _mm512_storeu_ps(py.add(i), sum);
            i += 16;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// Fused microkernel for matmul/syrk accumulation (FMA allowed).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn fma_axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm512_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let acc = _mm512_fmadd_ps(
                av,
                _mm512_loadu_ps(px.add(i)),
                _mm512_loadu_ps(py.add(i)),
            );
            _mm512_storeu_ps(py.add(i), acc);
            i += 16;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// [`super::avx2::pair_scan_gather`] widened to 8 f64 lanes: one
    /// `vgatherqps` pulls 8 f32 Gram entries through 8 i64 indices
    /// loaded straight from the `&[usize]` partition, widened exactly
    /// to f64.  Per-lane running best with first-wins blend masks,
    /// then the same lexicographic (dl, index) lane reduction — so
    /// the selected pair is bit-identical to the scalar and AVX2
    /// scans.
    ///
    /// SAFETY contract (caller): every `pruned[i] < g_row.len()`.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn pair_scan_gather(
        au: f64,
        wu2: f64,
        b: &[f64],
        wp: &[f64],
        g_row: &[f32],
        pruned: &[usize],
        best: f64,
    ) -> Option<(f64, usize)> {
        debug_assert_eq!(b.len(), wp.len());
        debug_assert_eq!(b.len(), pruned.len());
        let n = b.len();
        let mut i = 0usize;
        let mut cur: Option<(f64, usize)> = None;
        if n >= 16 {
            let au_v = _mm512_set1_pd(au);
            let wu2_v = _mm512_set1_pd(wu2);
            let mut best_v = _mm512_set1_pd(best);
            let mut idx_v = _mm512_set1_pd(-1.0);
            let mut lane =
                _mm512_setr_pd(0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0);
            let eight = _mm512_set1_pd(8.0);
            while i + 8 <= n {
                let bv = _mm512_loadu_pd(b.as_ptr().add(i));
                let wv = _mm512_loadu_pd(wp.as_ptr().add(i));
                // usize is 64-bit on x86-64, so eight pruned indices
                // load directly as the i64 gather offsets.
                let off = _mm512_loadu_epi64(
                    pruned.as_ptr().add(i) as *const i64);
                let g32 = _mm512_i64gather_ps::<4>(
                    off, g_row.as_ptr() as *const u8);
                let gv = _mm512_cvtps_pd(g32);
                // (au + b) - ((wu2 * wp) * gp): scalar rounding order.
                let dl = _mm512_sub_pd(
                    _mm512_add_pd(au_v, bv),
                    _mm512_mul_pd(_mm512_mul_pd(wu2_v, wv), gv),
                );
                let lt = _mm512_cmp_pd_mask::<_CMP_LT_OQ>(dl, best_v);
                best_v = _mm512_mask_blend_pd(lt, best_v, dl);
                idx_v = _mm512_mask_blend_pd(lt, idx_v, lane);
                lane = _mm512_add_pd(lane, eight);
                i += 8;
            }
            let mut bests = [0.0f64; 8];
            let mut idxs = [0.0f64; 8];
            _mm512_storeu_pd(bests.as_mut_ptr(), best_v);
            _mm512_storeu_pd(idxs.as_mut_ptr(), idx_v);
            // Lane l's best index is the first in that lane's
            // subsequence; the lexicographic (dl, idx) reduction then
            // recovers the global first-wins winner.
            for l in 0..8 {
                if idxs[l] < 0.0 {
                    continue;
                }
                let (dl, kp) = (bests[l], idxs[l] as usize);
                cur = match cur {
                    Some((cd, ck))
                        if !(dl < cd || (dl == cd && kp < ck)) =>
                    {
                        Some((cd, ck))
                    }
                    _ => Some((dl, kp)),
                };
            }
        }
        let mut best_dl = match cur {
            Some((cd, _)) => cd,
            None => best,
        };
        while i < n {
            let gp = g_row[pruned[i]] as f64;
            let dl = au + b[i] - wu2 * wp[i] * gp;
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
            i += 1;
        }
        cur
    }

    /// f32-accumulation Eq.-5 scan, 16 lanes per step.  Each `dl`
    /// follows the exact `scalar::pair_scan_f32` rounding sequence
    /// (no FMA), so the winner is bit-identical to the scalar f32
    /// body.  Lane indices are tracked as f32 — exact below 2^24,
    /// far above any layer width this scan sees.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn pair_scan_f32(
        au: f32,
        wu2: f32,
        b: &[f32],
        wp: &[f32],
        gp: &[f32],
        best: f32,
    ) -> Option<(f32, usize)> {
        debug_assert_eq!(b.len(), wp.len());
        debug_assert_eq!(b.len(), gp.len());
        let n = b.len();
        let mut i = 0usize;
        let mut cur: Option<(f32, usize)> = None;
        if n >= 32 {
            let au_v = _mm512_set1_ps(au);
            let wu2_v = _mm512_set1_ps(wu2);
            let mut best_v = _mm512_set1_ps(best);
            let mut idx_v = _mm512_set1_ps(-1.0);
            let mut lane = _mm512_setr_ps(
                0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0,
                11.0, 12.0, 13.0, 14.0, 15.0,
            );
            let sixteen = _mm512_set1_ps(16.0);
            while i + 16 <= n {
                let bv = _mm512_loadu_ps(b.as_ptr().add(i));
                let wv = _mm512_loadu_ps(wp.as_ptr().add(i));
                let gv = _mm512_loadu_ps(gp.as_ptr().add(i));
                // (au + b) - ((wu2 * wp) * gp): scalar rounding order.
                let dl = _mm512_sub_ps(
                    _mm512_add_ps(au_v, bv),
                    _mm512_mul_ps(_mm512_mul_ps(wu2_v, wv), gv),
                );
                let lt = _mm512_cmp_ps_mask::<_CMP_LT_OQ>(dl, best_v);
                best_v = _mm512_mask_blend_ps(lt, best_v, dl);
                idx_v = _mm512_mask_blend_ps(lt, idx_v, lane);
                lane = _mm512_add_ps(lane, sixteen);
                i += 16;
            }
            let mut bests = [0.0f32; 16];
            let mut idxs = [0.0f32; 16];
            _mm512_storeu_ps(bests.as_mut_ptr(), best_v);
            _mm512_storeu_ps(idxs.as_mut_ptr(), idx_v);
            for l in 0..16 {
                if idxs[l] < 0.0 {
                    continue;
                }
                let (dl, kp) = (bests[l], idxs[l] as usize);
                cur = match cur {
                    Some((cd, ck))
                        if !(dl < cd || (dl == cd && kp < ck)) =>
                    {
                        Some((cd, ck))
                    }
                    _ => Some((dl, kp)),
                };
            }
        }
        let mut best_dl = match cur {
            Some((cd, _)) => cd,
            None => best,
        };
        while i < n {
            let dl = au + b[i] - wu2 * wp[i] * gp[i];
            if dl < best_dl {
                best_dl = dl;
                cur = Some((dl, i));
            }
            i += 1;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn vecs(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..n).map(|_| rng.gaussian_f32()).collect();
        let b = (0..n).map(|_| rng.gaussian_f32()).collect();
        (a, b)
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                out.set(i, j, s as f32);
            }
        }
        out
    }

    #[test]
    fn active_arm_is_usable() {
        let arm = active();
        assert!(arms().contains(&arm));
    }

    #[test]
    fn select_rejects_unknown() {
        assert!(select("fancy").is_err());
    }

    #[test]
    fn dot_scalar_matches_reference() {
        for n in [0usize, 1, 3, 7, 8, 33, 257] {
            let (a, b) = vecs(n as u64, n);
            let want: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            let got = dot_arm(Arm::Scalar, &a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dot_arms_agree() {
        for n in [1usize, 5, 8, 15, 16, 17, 31, 32, 33, 100, 1023] {
            let (a, b) = vecs(100 + n as u64, n);
            let s = dot_arm(Arm::Scalar, &a, &b);
            for arm in arms() {
                let v = dot_arm(arm, &a, &b);
                assert!(
                    (s - v).abs() <= 1e-4 * s.abs().max(1.0),
                    "n={n} arm={arm:?}: scalar {s} vs {v}"
                );
            }
        }
    }

    #[test]
    fn axpy_arms_bit_identical() {
        for n in [1usize, 7, 8, 9, 64, 101] {
            let (x, y0) = vecs(200 + n as u64, n);
            let mut ys = y0.clone();
            axpy_arm(Arm::Scalar, -1.75, &x, &mut ys);
            for arm in arms() {
                let mut ya = y0.clone();
                axpy_arm(arm, -1.75, &x, &mut ya);
                for i in 0..n {
                    assert_eq!(
                        ys[i].to_bits(),
                        ya[i].to_bits(),
                        "n={n} i={i} arm={arm:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn axpy_dot_updates_and_returns_dot() {
        for arm in arms() {
            for n in [1usize, 4, 11, 64, 130] {
                let (x, y0) = vecs(300 + n as u64, n);
                let mut y = y0.clone();
                let got = axpy_dot_arm(arm, 0.5, &x, &mut y);
                // Update half must equal a plain axpy bit-for-bit.
                let mut want_y = y0.clone();
                axpy_arm(Arm::Scalar, 0.5, &x, &mut want_y);
                for i in 0..n {
                    assert_eq!(y[i].to_bits(), want_y[i].to_bits());
                }
                let want: f64 = x
                    .iter()
                    .zip(&y)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                assert!(
                    (got as f64 - want).abs()
                        <= 1e-4 * want.abs().max(1.0),
                    "arm={arm:?} n={n}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn matmul_blocked_matches_naive_ragged() {
        let mut rng = Rng::new(5);
        for (n, k, m) in [(1, 1, 1), (2, 3, 4), (7, 13, 5), (20, 33, 17)] {
            let a = Matrix::from_fn(n, k, |_, _| rng.gaussian_f32());
            let b = Matrix::from_fn(k, m, |_, _| rng.gaussian_f32());
            let want = naive_matmul(&a, &b);
            for arm in arms() {
                let got = matmul_arm(arm, &a, &b);
                assert!(
                    got.max_abs_diff(&want) < 1e-3,
                    "({n},{k},{m}) arm={arm:?}"
                );
            }
        }
    }

    #[test]
    fn matmul_scalar_matches_legacy_ikj_bitwise() {
        // The legacy loop, inlined here as the bit-exactness oracle.
        let legacy = |a: &Matrix, b: &Matrix| -> Matrix {
            let (n, k, m) = (a.rows, a.cols, b.cols);
            let mut out = Matrix::zeros(n, m);
            for i in 0..n {
                let arow = a.row(i);
                let orow = &mut out.data[i * m..(i + 1) * m];
                for (kk, &av) in arow.iter().enumerate().take(k) {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * m..(kk + 1) * m];
                    for j in 0..m {
                        orow[j] += av * brow[j];
                    }
                }
            }
            out
        };
        let mut rng = Rng::new(6);
        for (n, k, m) in [(3, 200, 5), (9, 150, 700), (4, 129, 513)] {
            let a = Matrix::from_fn(n, k, |_, _| rng.gaussian_f32());
            let b = Matrix::from_fn(k, m, |_, _| rng.gaussian_f32());
            let want = legacy(&a, &b);
            let got = matmul_arm(Arm::Scalar, &a, &b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn matmul_par_is_bit_identical_across_threads() {
        let mut rng = Rng::new(12);
        for (n, k, m) in [(1usize, 5usize, 3usize), (7, 40, 11),
                          (23, 130, 520)] {
            let a = Matrix::from_fn(n, k, |_, _| rng.gaussian_f32());
            let b = Matrix::from_fn(k, m, |_, _| rng.gaussian_f32());
            for arm in arms() {
                let single = matmul_arm(arm, &a, &b);
                for threads in [2usize, 4, 9] {
                    let par = matmul_arm_par(arm, &a, &b, threads);
                    for (x, y) in par.data.iter().zip(&single.data) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "({n},{k},{m}) arm={arm:?} \
                             threads={threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn syrk_matches_transpose_matmul() {
        let mut rng = Rng::new(7);
        for (t, d) in [(5, 3), (20, 13), (64, 33)] {
            let x = Matrix::from_fn(t, d, |_, _| rng.gaussian_f32());
            let want = x.transpose().matmul(&x);
            for arm in arms() {
                for threads in [1usize, 3] {
                    let mut g = Matrix::zeros(d, d);
                    syrk_arm(arm, &mut g, &x, threads);
                    assert!(
                        g.max_abs_diff(&want) < 1e-3,
                        "t={t} d={d} arm={arm:?} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn syrk_is_exactly_symmetric_and_thread_invariant() {
        let mut rng = Rng::new(8);
        let (t, d) = (40, 29);
        let x = Matrix::from_fn(t, d, |_, _| rng.gaussian_f32());
        for arm in arms() {
            let mut g1 = Matrix::zeros(d, d);
            syrk_arm(arm, &mut g1, &x, 1);
            for i in 0..d {
                for j in 0..d {
                    assert_eq!(
                        g1.at(i, j).to_bits(),
                        g1.at(j, i).to_bits()
                    );
                }
            }
            let mut g4 = Matrix::zeros(d, d);
            syrk_arm(arm, &mut g4, &x, 4);
            for (a, b) in g1.data.iter().zip(&g4.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn pair_scan_matches_bruteforce_first_wins() {
        let mut rng = Rng::new(9);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 100] {
            let b: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let wp: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let gp: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let (au, wu2) = (0.3f64, -1.1f64);
            for best in [f64::INFINITY, 0.0] {
                let mut want: Option<(f64, usize)> = None;
                let mut cur = best;
                for i in 0..n {
                    let dl = au + b[i] - wu2 * wp[i] * gp[i];
                    if dl < cur {
                        cur = dl;
                        want = Some((dl, i));
                    }
                }
                for arm in arms() {
                    let got =
                        pair_scan_arm(arm, au, wu2, &b, &wp, &gp, best);
                    match (got, want) {
                        (None, None) => {}
                        (Some((gd, gi)), Some((wd, wi))) => {
                            assert_eq!(gd.to_bits(), wd.to_bits());
                            assert_eq!(gi, wi, "n={n} arm={arm:?}");
                        }
                        other => panic!("n={n} arm={arm:?}: {other:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn pair_scan_gather_matches_packed_bitwise() {
        // The gathered scan must select the exact pair (value and
        // index, bit-for-bit) that the packed scan selects, for every
        // arm, on ragged sizes and sparse index sets.
        let mut rng = Rng::new(13);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 12, 31, 100] {
            let d = 4 * n + 8;
            let g_row: Vec<f32> =
                (0..d).map(|_| rng.gaussian_f32()).collect();
            // Strictly increasing sparse indices, like a pruned
            // partition.
            let mut pruned: Vec<usize> = Vec::with_capacity(n);
            let mut at = rng.usize_below(4);
            for _ in 0..n {
                pruned.push(at.min(d - 1));
                at += 1 + rng.usize_below(3);
            }
            let b: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let wp: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let gp: Vec<f64> =
                pruned.iter().map(|&p| g_row[p] as f64).collect();
            let (au, wu2) = (-0.7f64, 1.9f64);
            for best in [f64::INFINITY, 0.0] {
                let want = pair_scan_arm(Arm::Scalar, au, wu2, &b, &wp,
                                         &gp, best);
                for arm in arms() {
                    let got = pair_scan_gather_arm(arm, au, wu2, &b,
                                                   &wp, &g_row, &pruned,
                                                   best);
                    match (got, want) {
                        (None, None) => {}
                        (Some((gd, gi)), Some((wd, wi))) => {
                            assert_eq!(gd.to_bits(), wd.to_bits(),
                                       "n={n} arm={arm:?}");
                            assert_eq!(gi, wi, "n={n} arm={arm:?}");
                        }
                        other => panic!("n={n} arm={arm:?}: {other:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn pair_scan_gather_breaks_ties_by_first_index() {
        let n = 11;
        let b = vec![1.0f64; n];
        let wp = vec![0.0f64; n];
        let g_row = vec![0.5f32; 64];
        let pruned: Vec<usize> = (0..n).map(|i| 3 * i).collect();
        for arm in arms() {
            let got = pair_scan_gather_arm(arm, -2.0, 1.0, &b, &wp,
                                           &g_row, &pruned,
                                           f64::INFINITY);
            assert_eq!(got, Some((-1.0, 0)), "arm={arm:?}");
        }
    }

    #[test]
    fn pair_scan_f32_arms_bit_identical() {
        // Scalar-f32 vs avx512-f32 (when present) must pick the same
        // pair bit-for-bit: the wide body keeps the per-element f32
        // rounding sequence and first-wins lane reduction.
        let mut rng = Rng::new(21);
        for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 100, 257] {
            let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let wp: Vec<f32> =
                (0..n).map(|_| rng.gaussian_f32()).collect();
            let gp: Vec<f32> =
                (0..n).map(|_| rng.gaussian_f32()).collect();
            let (au, wu2) = (0.3f32, -1.1f32);
            for best in [f32::INFINITY, 0.0] {
                let want = pair_scan_f32_arm(Arm::Scalar, au, wu2, &b,
                                             &wp, &gp, best);
                for arm in arms() {
                    let got = pair_scan_f32_arm(arm, au, wu2, &b, &wp,
                                                &gp, best);
                    match (got, want) {
                        (None, None) => {}
                        (Some((gd, gi)), Some((wd, wi))) => {
                            assert_eq!(gd.to_bits(), wd.to_bits(),
                                       "n={n} arm={arm:?}");
                            assert_eq!(gi, wi, "n={n} arm={arm:?}");
                        }
                        other => panic!("n={n} arm={arm:?}: {other:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn pair_scan_f32_tracks_f64_oracle() {
        // The f32 scan is not bit-exact against the f64 scan — that's
        // the point of keeping f64 on the mask path — but its best
        // delta must track the oracle's to f32 precision on
        // well-separated inputs.
        let mut rng = Rng::new(22);
        for n in [1usize, 9, 33, 64, 200] {
            let b64: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let wp64: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let gp64: Vec<f64> =
                (0..n).map(|_| rng.gaussian_f32() as f64).collect();
            let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let wp32: Vec<f32> =
                wp64.iter().map(|&v| v as f32).collect();
            let gp32: Vec<f32> =
                gp64.iter().map(|&v| v as f32).collect();
            let (au, wu2) = (0.3f64, -1.1f64);
            let want = pair_scan_arm(Arm::Scalar, au, wu2, &b64, &wp64,
                                     &gp64, f64::INFINITY)
                .expect("n >= 1 with infinite best always selects");
            for arm in arms() {
                let got = pair_scan_f32_arm(arm, au as f32, wu2 as f32,
                                            &b32, &wp32, &gp32,
                                            f32::INFINITY)
                    .expect("f32 scan selects too");
                assert!(
                    (got.0 as f64 - want.0).abs()
                        <= 1e-4 * want.0.abs().max(1.0),
                    "n={n} arm={arm:?}: f32 {} vs f64 {}",
                    got.0,
                    want.0
                );
            }
        }
    }

    #[test]
    fn pair_scan_breaks_ties_by_first_index() {
        // All entries produce the identical dl; the first index wins.
        let n = 13;
        let b = vec![1.0f64; n];
        let wp = vec![0.0f64; n];
        let gp = vec![0.0f64; n];
        for arm in arms() {
            let got =
                pair_scan_arm(arm, -2.0, 1.0, &b, &wp, &gp, f64::INFINITY);
            assert_eq!(got, Some((-1.0, 0)), "arm={arm:?}");
        }
    }
}
