//! Seeded PRNG: SplitMix64 for seeding, Xoshiro256++ for the stream.
//!
//! Built in-repo because no external RNG crate is available offline; the
//! generators follow the public-domain reference implementations
//! (Blackman & Vigna).  Everything downstream (corpus generation, model
//! init on the Rust side, property tests, samplers) threads through this
//! type so every run is reproducible from a single `u64` seed.

/// SplitMix64: used to expand a single seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-task streams).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index needs positive mass");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_mass() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(13);
        let got = r.sample_distinct(50, 20);
        let mut s = got.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(got.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(21);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
