//! Declarative CLI flag parser (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    MissingRequired(String),
    InvalidValue { flag: String, value: String, expected: &'static str },
    UnexpectedPositional(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) =>
                write!(f, "flag --{n} expects a value"),
            CliError::MissingRequired(what) =>
                write!(f, "missing required {what}"),
            CliError::InvalidValue { flag, value, expected } =>
                write!(f, "invalid value for --{flag}: {value:?} \
                           ({expected})"),
            CliError::UnexpectedPositional(a) =>
                write!(f, "unexpected positional argument {a:?}"),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
    is_bool: bool,
    required: bool,
}

/// Declarative argument specification for one subcommand.
pub struct ArgSpec {
    program: String,
    about: &'static str,
    flags: Vec<FlagSpec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // name, help, req
}

pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: impl Into<String>, about: &'static str) -> Self {
        Self { program: program.into(), about, flags: Vec::new(),
               positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str,
                help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: Some(default),
                                   is_bool: false, required: false });
        self
    }

    pub fn required_flag(mut self, name: &'static str,
                         help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None,
                                   is_bool: false, required: true });
        self
    }

    pub fn bool_flag(mut self, name: &'static str,
                     help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None,
                                   is_bool: true, required: false });
        self
    }

    /// Boolean flag that defaults to *on*; disable with `--name=false`.
    pub fn bool_flag_on(mut self, name: &'static str,
                        help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: Some("true"),
                                   is_bool: true, required: false });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str,
                      required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program,
                            self.about, self.program);
        for (name, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{name}>"));
            } else {
                s.push_str(&format!(" [{name}]"));
            }
        }
        s.push_str(" [FLAGS]\n\nFLAGS:\n");
        for f in &self.flags {
            let d = match f.default {
                Some(d) if !f.is_bool => format!(" (default: {d})"),
                Some(_) if f.is_bool =>
                    " (default: on; =false disables)".to_string(),
                _ => String::new(),
            };
            let req = if f.required { " (required)" } else { "" };
            s.push_str(&format!("  --{:<22} {}{}{}\n", f.name, f.help, d,
                                req));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        let mut positionals = Vec::new();
        for f in &self.flags {
            if let Some(d) = f.default {
                if !f.is_bool {
                    values.insert(f.name.to_string(), d.to_string());
                }
            }
            if f.is_bool {
                bools.insert(f.name.to_string(),
                             f.default == Some("true"));
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if name == "help" {
                    print!("{}", self.usage());
                    std::process::exit(0);
                }
                let spec = self.flags.iter().find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                if spec.is_bool {
                    let v = match inline.as_deref() {
                        Some("false") | Some("0") => false,
                        _ => true,
                    };
                    bools.insert(name, v);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(
                                    || CliError::MissingValue(name.clone()))?
                                .clone()
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                if positionals.len() >= self.positionals.len() {
                    return Err(CliError::UnexpectedPositional(a.clone()));
                }
                positionals.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !values.contains_key(f.name) {
                return Err(CliError::MissingRequired(
                    format!("flag --{}", f.name)));
            }
        }
        for (idx, (name, _, req)) in self.positionals.iter().enumerate() {
            if *req && positionals.len() <= idx {
                return Err(CliError::MissingRequired(
                    format!("positional <{name}>")));
            }
        }
        Ok(Args { values, bools, positionals })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str)
        -> Result<T, CliError> {
        self.get(name).parse().map_err(|_| CliError::InvalidValue {
            flag: name.to_string(),
            value: self.get(name).to_string(),
            expected: std::any::type_name::<T>(),
        })
    }

    /// Comma-separated list of T.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str)
        -> Result<Vec<T>, CliError> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|_| CliError::InvalidValue {
                flag: name.to_string(),
                value: s.to_string(),
                expected: std::any::type_name::<T>(),
            }))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "a test command")
            .flag("alpha", "1.5", "alpha value")
            .required_flag("name", "the name")
            .bool_flag("verbose", "chatty")
            .positional("input", "input file", true)
    }

    fn argv(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = spec()
            .parse(&argv(&["file.txt", "--name", "x", "--verbose",
                           "--alpha=2.5"]))
            .unwrap();
        assert_eq!(a.positional(0), Some("file.txt"));
        assert_eq!(a.get("name"), "x");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.parse_num::<f64>("alpha").unwrap(), 2.5);
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&argv(&["f", "--name", "n"])).unwrap();
        assert_eq!(a.get("alpha"), "1.5");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_flag() {
        assert!(matches!(spec().parse(&argv(&["f"])),
                         Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn missing_required_positional() {
        assert!(matches!(spec().parse(&argv(&["--name", "n"])),
                         Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(spec().parse(&argv(&["f", "--name", "n", "--bogus"])),
                         Err(CliError::UnknownFlag(_))));
    }

    #[test]
    fn list_parsing() {
        let s = ArgSpec::new("t", "x").flag("ks", "1,2,5", "list");
        let a = s.parse(&[]).unwrap();
        assert_eq!(a.parse_list::<usize>("ks").unwrap(), vec![1, 2, 5]);
    }

    #[test]
    fn bool_flag_explicit_false() {
        let s = ArgSpec::new("t", "x").bool_flag("on", "y");
        let a = s.parse(&argv(&["--on=false"])).unwrap();
        assert!(!a.get_bool("on"));
    }

    #[test]
    fn bool_flag_on_defaults_true_and_disables() {
        let s = || ArgSpec::new("t", "x").bool_flag_on("fast", "y");
        assert!(s().parse(&[]).unwrap().get_bool("fast"));
        assert!(!s().parse(&argv(&["--fast=false"])).unwrap()
                .get_bool("fast"));
        assert!(s().parse(&argv(&["--fast"])).unwrap().get_bool("fast"));
    }
}
