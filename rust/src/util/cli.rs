//! Declarative CLI flag parser (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    MissingRequired(String),
    InvalidValue { flag: String, value: String, expected: &'static str },
    UnexpectedPositional(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) =>
                write!(f, "flag --{n} expects a value"),
            CliError::MissingRequired(what) =>
                write!(f, "missing required {what}"),
            CliError::InvalidValue { flag, value, expected } =>
                write!(f, "invalid value for --{flag}: {value:?} \
                           ({expected})"),
            CliError::UnexpectedPositional(a) =>
                write!(f, "unexpected positional argument {a:?}"),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
    is_bool: bool,
    required: bool,
}

/// Declarative argument specification for one subcommand.
pub struct ArgSpec {
    program: String,
    about: &'static str,
    flags: Vec<FlagSpec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // name, help, req
}

pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: impl Into<String>, about: &'static str) -> Self {
        Self { program: program.into(), about, flags: Vec::new(),
               positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str,
                help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: Some(default),
                                   is_bool: false, required: false });
        self
    }

    pub fn required_flag(mut self, name: &'static str,
                         help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None,
                                   is_bool: false, required: true });
        self
    }

    pub fn bool_flag(mut self, name: &'static str,
                     help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None,
                                   is_bool: true, required: false });
        self
    }

    /// Boolean flag that defaults to *on*; disable with `--name=false`.
    pub fn bool_flag_on(mut self, name: &'static str,
                        help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: Some("true"),
                                   is_bool: true, required: false });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str,
                      required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program,
                            self.about, self.program);
        for (name, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{name}>"));
            } else {
                s.push_str(&format!(" [{name}]"));
            }
        }
        s.push_str(" [FLAGS]\n\nFLAGS:\n");
        for f in &self.flags {
            let d = match f.default {
                Some(d) if !f.is_bool => format!(" (default: {d})"),
                Some(_) if f.is_bool =>
                    " (default: on; =false disables)".to_string(),
                _ => String::new(),
            };
            let req = if f.required { " (required)" } else { "" };
            s.push_str(&format!("  --{:<22} {}{}{}\n", f.name, f.help, d,
                                req));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        let mut positionals = Vec::new();
        for f in &self.flags {
            if let Some(d) = f.default {
                if !f.is_bool {
                    values.insert(f.name.to_string(), d.to_string());
                }
            }
            if f.is_bool {
                bools.insert(f.name.to_string(),
                             f.default == Some("true"));
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if name == "help" {
                    print!("{}", self.usage());
                    std::process::exit(0);
                }
                let spec = self.flags.iter().find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                if spec.is_bool {
                    let v = match inline.as_deref() {
                        Some("false") | Some("0") => false,
                        _ => true,
                    };
                    bools.insert(name, v);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(
                                    || CliError::MissingValue(name.clone()))?
                                .clone()
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                if positionals.len() >= self.positionals.len() {
                    return Err(CliError::UnexpectedPositional(a.clone()));
                }
                positionals.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !values.contains_key(f.name) {
                return Err(CliError::MissingRequired(
                    format!("flag --{}", f.name)));
            }
        }
        for (idx, (name, _, req)) in self.positionals.iter().enumerate() {
            if *req && positionals.len() <= idx {
                return Err(CliError::MissingRequired(
                    format!("positional <{name}>")));
            }
        }
        Ok(Args { values, bools, positionals })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str)
        -> Result<T, CliError> {
        self.get(name).parse().map_err(|_| CliError::InvalidValue {
            flag: name.to_string(),
            value: self.get(name).to_string(),
            expected: std::any::type_name::<T>(),
        })
    }

    /// Comma-separated list of T.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str)
        -> Result<Vec<T>, CliError> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|_| CliError::InvalidValue {
                flag: name.to_string(),
                value: s.to_string(),
                expected: std::any::type_name::<T>(),
            }))
            .collect()
    }
}

/// Parsed values of the pool/backend flag block shared by `prune`,
/// `sweep` and `report` ([`ArgSpec::pool_flags`]).
#[derive(Clone, Debug)]
pub struct PoolFlags {
    pub kernels: String,
    /// Raw `--devices` value (0 = all cores; resolution to a worker
    /// count is the caller's).
    pub devices: usize,
    pub device_mem_budget_mib: u64,
    /// Raw `--threads` value (0 = all cores).
    pub threads: usize,
}

/// Parsed values of the journaling + fault-recovery flag block shared
/// by `prune` and `sweep` ([`ArgSpec::journal_flags`]).
#[derive(Clone, Debug)]
pub struct JournalFlags {
    pub max_shard_retries: usize,
    pub quarantine_after: u64,
    pub journal: Option<std::path::PathBuf>,
    pub resume: bool,
    /// Raw fault-injection spec ("" = none); parsed by the caller —
    /// the runtime layer owns `FaultPlan` and this module stays
    /// dependency-free.
    pub fault_plan: String,
}

impl ArgSpec {
    /// Register the pool/backend flag block shared by the pruning
    /// subcommands (`--kernels`, `--devices`, `--device-mem-budget`,
    /// `--threads`), so `prune`, `sweep` and `report` cannot drift.
    /// Parse with [`Args::pool_flags`].
    pub fn pool_flags(self, devices_default: &'static str) -> Self {
        self.flag("kernels", "auto", "kernel dispatch arm: auto|\
                                      scalar|simd|avx512 (scalar for \
                                      cross-arm parity testing)")
            .flag("devices", devices_default,
                  "offload runtime service workers (0 = all cores); \
                   >1 refines layers concurrently across devices")
            .flag("device-mem-budget", "512",
                  "per-device buffer-cache budget in MiB \
                   (0 = unlimited)")
            .flag("threads", "0", "worker threads (0 = all cores)")
    }

    /// Register the journaling + fault-recovery flag block
    /// (`--max-shard-retries`, `--quarantine-after`, `--journal`,
    /// `--resume`, `--fault-plan`).  Parse with
    /// [`Args::journal_flags`].
    pub fn journal_flags(self, journal_default: &'static str) -> Self {
        self.flag("max-shard-retries", "2",
                  "redispatches per shard for transient worker \
                   failures")
            .flag("quarantine-after", "2",
                  "consecutive shard failures before a worker is \
                   quarantined (0 = never)")
            .flag("journal", journal_default,
                  "mask journal directory for resumable runs (\"\" \
                   disables journaling)")
            .bool_flag("resume", "resume from the journal: restore \
                                  completed blocks and continue")
            .flag("fault-plan", "", "deterministic fault-injection \
                                     spec (e.g. \
                                     \"seed=7;rate=0.05;kill=1\"); \
                                     also SPARSESWAPS_FAULTS")
    }
}

impl Args {
    /// Parse the [`ArgSpec::pool_flags`] block.
    pub fn pool_flags(&self) -> Result<PoolFlags, CliError> {
        Ok(PoolFlags {
            kernels: self.get("kernels").to_string(),
            devices: self.parse_num("devices")?,
            device_mem_budget_mib: self.parse_num(
                "device-mem-budget")?,
            threads: self.parse_num("threads")?,
        })
    }

    /// Parse the [`ArgSpec::journal_flags`] block.
    pub fn journal_flags(&self) -> Result<JournalFlags, CliError> {
        Ok(JournalFlags {
            max_shard_retries: self.parse_num("max-shard-retries")?,
            quarantine_after: self.parse_num("quarantine-after")?,
            journal: match self.get("journal") {
                "" => None,
                dir => Some(std::path::PathBuf::from(dir)),
            },
            resume: self.get_bool("resume"),
            fault_plan: self.get("fault-plan").to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "a test command")
            .flag("alpha", "1.5", "alpha value")
            .required_flag("name", "the name")
            .bool_flag("verbose", "chatty")
            .positional("input", "input file", true)
    }

    fn argv(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = spec()
            .parse(&argv(&["file.txt", "--name", "x", "--verbose",
                           "--alpha=2.5"]))
            .unwrap();
        assert_eq!(a.positional(0), Some("file.txt"));
        assert_eq!(a.get("name"), "x");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.parse_num::<f64>("alpha").unwrap(), 2.5);
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&argv(&["f", "--name", "n"])).unwrap();
        assert_eq!(a.get("alpha"), "1.5");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_flag() {
        assert!(matches!(spec().parse(&argv(&["f"])),
                         Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn missing_required_positional() {
        assert!(matches!(spec().parse(&argv(&["--name", "n"])),
                         Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(spec().parse(&argv(&["f", "--name", "n", "--bogus"])),
                         Err(CliError::UnknownFlag(_))));
    }

    #[test]
    fn list_parsing() {
        let s = ArgSpec::new("t", "x").flag("ks", "1,2,5", "list");
        let a = s.parse(&[]).unwrap();
        assert_eq!(a.parse_list::<usize>("ks").unwrap(), vec![1, 2, 5]);
    }

    #[test]
    fn bool_flag_explicit_false() {
        let s = ArgSpec::new("t", "x").bool_flag("on", "y");
        let a = s.parse(&argv(&["--on=false"])).unwrap();
        assert!(!a.get_bool("on"));
    }

    #[test]
    fn shared_flag_blocks_register_and_parse() {
        let s = ArgSpec::new("t", "x")
            .pool_flags("0")
            .journal_flags("reports/j");
        let a = s.parse(&argv(&["--devices", "3", "--threads=2",
                                "--journal", "", "--resume"]))
            .unwrap();
        let pf = a.pool_flags().unwrap();
        assert_eq!(pf.kernels, "auto");
        assert_eq!(pf.devices, 3);
        assert_eq!(pf.device_mem_budget_mib, 512);
        assert_eq!(pf.threads, 2);
        let jf = a.journal_flags().unwrap();
        assert_eq!(jf.max_shard_retries, 2);
        assert_eq!(jf.quarantine_after, 2);
        assert_eq!(jf.journal, None, "--journal \"\" disables");
        assert!(jf.resume);
        assert_eq!(jf.fault_plan, "");
        // Defaults flow through untouched.
        let b = ArgSpec::new("t", "x").pool_flags("1")
            .journal_flags("reports/j").parse(&[]).unwrap();
        assert_eq!(b.pool_flags().unwrap().devices, 1);
        assert_eq!(b.journal_flags().unwrap().journal,
                   Some(std::path::PathBuf::from("reports/j")));
    }

    #[test]
    fn bool_flag_on_defaults_true_and_disables() {
        let s = || ArgSpec::new("t", "x").bool_flag_on("fast", "y");
        assert!(s().parse(&[]).unwrap().get_bool("fast"));
        assert!(!s().parse(&argv(&["--fast=false"])).unwrap()
                .get_bool("fast"));
        assert!(s().parse(&argv(&["--fast"])).unwrap().get_bool("fast"));
    }
}
