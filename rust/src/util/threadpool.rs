//! Fixed-size thread pool + scoped data-parallel helpers.
//!
//! Built in-repo (no rayon/tokio offline).  Two entry points:
//!   * [`ThreadPool`] — long-lived workers with a job queue, used by the
//!     coordinator to refine several layers concurrently;
//!   * [`parallel_chunks`] — scoped fork/join over an index range for
//!     one-off data parallelism (gram reduction, eval batches).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: mpsc::Sender<Message>,
    queue_guard: Arc<Mutex<mpsc::Receiver<Message>>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let queue_guard = Arc::new(Mutex::new(receiver));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&queue_guard);
            let pend = Arc::clone(&pending);
            workers.push(thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match msg {
                    Ok(Message::Run(job)) => {
                        // Contain panics so a failing job can neither
                        // kill the worker nor leave the pending counter
                        // stuck (which would hang wait() forever).
                        // Callers that need the job's outcome observe it
                        // through the job's own channel, not the panic.
                        let _ = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(job));
                        let (lock, cv) = &*pend;
                        let mut cnt = lock.lock().unwrap();
                        *cnt -= 1;
                        if *cnt == 0 {
                            cv.notify_all();
                        }
                    }
                    Ok(Message::Shutdown) | Err(_) => break,
                }
            }));
        }
        Self { workers, sender, queue_guard, pending }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.sender.send(Message::Run(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }

    /// Run a batch of *borrowing* jobs to completion on the pool
    /// (scoped fork/join): submits every job, then blocks until all of
    /// them (and any other pending work) have finished, so the jobs
    /// may capture non-`'static` references — e.g. zero-copy
    /// [`crate::util::tensor::GramView`]s into calibration state.
    pub fn run_scoped<'env>(&self,
                            jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        for job in jobs {
            // SAFETY: `wait()` below blocks until every job submitted
            // here has completed (worker panics are contained and
            // still decrement the pending counter), so no job —
            // and therefore no borrow it captures — outlives 'env.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>,
                                      Box<dyn FnOnce() + Send + 'static>>(
                    job)
            };
            self.submit(job);
        }
        self.wait();
    }
}

/// Process-wide shared pool for kernel-level data parallelism (the
/// syrk row panels).  Lazily sized to the host's parallelism.  Do not
/// call blocking scoped work on it from *inside* one of its own
/// workers (possible starvation); the crate only uses it from
/// top-level compute calls.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait();
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        // Keep the receiver alive until workers exit.
        let _guard = Arc::clone(&self.queue_guard);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Reasonable default parallelism for this host.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped fork/join: run `f(start, end)` over `n_items` split into
/// roughly equal contiguous chunks across `n_threads` threads.
pub fn parallel_chunks<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n_items == 0 {
        return;
    }
    let n_threads = n_threads.max(1).min(n_items);
    let chunk = n_items.div_ceil(n_threads);
    thread::scope(|s| {
        for t in 0..n_threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> =
        out.iter_mut().map(Mutex::new).collect();
    let next = AtomicUsize::new(0);
    let n_threads = n_threads.max(1).min(n.max(1));
    thread::scope(|s| {
        for _ in 0..n_threads {
            let f = &f;
            let next = &next;
            let slots = &slots;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::Relaxed), 10 * (round + 1));
        }
    }

    #[test]
    fn parallel_chunks_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(97, 8, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(50, 6, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("job failure"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // wait() must not hang, and the workers must keep serving.
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn run_scoped_allows_borrowed_jobs() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let total = AtomicU64::new(0);
        {
            let data = &data;
            let total = &total;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|t| {
                    Box::new(move || {
                        let s: u64 = data.iter()
                            .skip(t)
                            .step_by(4)
                            .sum();
                        total.fetch_add(s, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn global_pool_is_shared_and_reusable() {
        for _ in 0..2 {
            let counter = AtomicU64::new(0);
            let c = &counter;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            global().run_scoped(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 8);
        }
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }
}
