//! Fixed-size thread pool + scoped data-parallel helpers.
//!
//! Built in-repo (no rayon/tokio offline).  Two entry points:
//!   * [`ThreadPool`] — long-lived workers with a job queue, used by the
//!     coordinator to refine several layers concurrently;
//!   * [`parallel_chunks`] — scoped fork/join over an index range for
//!     one-off data parallelism (gram reduction, eval batches).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock recovering from poisoning.  Every critical section in this
/// module performs a single-step mutation (counter bump/decrement,
/// queue recv) that leaves the guarded state valid at every instant,
/// and job panics are contained by `catch_unwind` before they can
/// unwind through one — so a poisoned lock only means *some* thread
/// panicked elsewhere, never that the data is torn.  Propagating the
/// poison would wedge every surviving worker (and hang `wait`)
/// instead of just the thread that died.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: mpsc::Sender<Message>,
    queue_guard: Arc<Mutex<mpsc::Receiver<Message>>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    busy: Arc<Vec<AtomicU64>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let queue_guard = Arc::new(Mutex::new(receiver));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let busy: Arc<Vec<AtomicU64>> =
            Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
        let mut workers = Vec::with_capacity(n);
        for wi in 0..n {
            let rx = Arc::clone(&queue_guard);
            let pend = Arc::clone(&pending);
            let busy = Arc::clone(&busy);
            workers.push(thread::spawn(move || loop {
                let msg = {
                    let guard = relock(&rx);
                    guard.recv()
                };
                match msg {
                    Ok(Message::Run(job)) => {
                        // Contain panics so a failing job can neither
                        // kill the worker nor leave the pending counter
                        // stuck (which would hang wait() forever).
                        // Callers that need the job's outcome observe it
                        // through the job's own channel, not the panic.
                        let t0 = Instant::now();
                        let _ = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(job));
                        busy[wi].fetch_add(
                            t0.elapsed().as_nanos() as u64,
                            Ordering::Relaxed);
                        let (lock, cv) = &*pend;
                        let mut cnt = relock(lock);
                        *cnt -= 1;
                        if *cnt == 0 {
                            cv.notify_all();
                        }
                    }
                    Ok(Message::Shutdown) | Err(_) => break,
                }
            }));
        }
        Self { workers, sender, queue_guard, pending, busy }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Cumulative nanoseconds each worker has spent inside jobs — the
    /// load-balance diagnostic behind the shard bench's imbalance
    /// metric (max/mean busy time across workers).
    pub fn busy_nanos(&self) -> Vec<u64> {
        self.busy.iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *relock(lock) += 1;
        }
        self.sender.send(Message::Run(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut cnt = relock(lock);
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Run a batch of *borrowing* jobs to completion on the pool
    /// (scoped fork/join): submits every job, then blocks until all of
    /// *this batch* has finished, so the jobs may capture
    /// non-`'static` references — e.g. zero-copy
    /// [`crate::util::tensor::GramView`]s into calibration state.
    ///
    /// Completion is tracked per batch, not pool-wide: concurrent
    /// `run_scoped` callers on the shared [`global`] pool (several
    /// runtime-service workers running interp matmuls, say) only wait
    /// for their own jobs instead of convoying on each other's.
    pub fn run_scoped<'env>(&self,
                            jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        // Batch-local completion count, decremented by a drop guard
        // so a panicking job (contained by the worker) still counts
        // down and the wait below cannot hang.
        struct BatchGuard(Arc<(Mutex<usize>, std::sync::Condvar)>);
        impl Drop for BatchGuard {
            fn drop(&mut self) {
                let (lock, cv) = &*self.0;
                // Recover from poisoning: the count stays valid (the
                // only mutation is this decrement) and refusing would
                // hang the batch wait below forever.
                let mut cnt = relock(lock);
                *cnt -= 1;
                if *cnt == 0 {
                    cv.notify_all();
                }
            }
        }
        let batch = Arc::new((Mutex::new(jobs.len()),
                              std::sync::Condvar::new()));
        for job in jobs {
            // SAFETY: the batch wait below blocks until every job
            // submitted here has completed (worker panics are
            // contained and the drop guard still counts down), so no
            // job — and therefore no borrow it captures — outlives
            // 'env.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>,
                                      Box<dyn FnOnce() + Send + 'static>>(
                    job)
            };
            let guard = BatchGuard(Arc::clone(&batch));
            self.submit(move || {
                let _guard = guard;
                job();
            });
        }
        let (lock, cv) = &*batch;
        let mut cnt = relock(lock);
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Process-wide shared pool for kernel-level data parallelism (the
/// syrk row panels).  Lazily sized to the host's parallelism.  Do not
/// call blocking scoped work on it from *inside* one of its own
/// workers (possible starvation); the crate only uses it from
/// top-level compute calls.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait();
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        // Keep the receiver alive until workers exit.
        let _guard = Arc::clone(&self.queue_guard);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Reasonable default parallelism for this host.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped fork/join: run `f(start, end)` over `n_items` split into
/// roughly equal contiguous chunks across `n_threads` threads.
pub fn parallel_chunks<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n_items == 0 {
        return;
    }
    let n_threads = n_threads.max(1).min(n_items);
    let chunk = n_items.div_ceil(n_threads);
    thread::scope(|s| {
        for t in 0..n_threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> =
        out.iter_mut().map(Mutex::new).collect();
    let next = AtomicUsize::new(0);
    let n_threads = n_threads.max(1).min(n.max(1));
    thread::scope(|s| {
        for _ in 0..n_threads {
            let f = &f;
            let next = &next;
            let slots = &slots;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::Relaxed), 10 * (round + 1));
        }
    }

    #[test]
    fn parallel_chunks_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(97, 8, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(50, 6, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("job failure"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // wait() must not hang, and the workers must keep serving.
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn pool_survives_poisoned_pending_lock() {
        let pool = ThreadPool::new(2);
        // Poison the pending lock by panicking while holding it;
        // `relock` recovery must keep submit/wait working.
        let pend = Arc::clone(&pool.pending);
        let _ = thread::spawn(move || {
            let _g = pend.0.lock().unwrap();
            panic!("poison pending lock");
        })
        .join();
        assert!(pool.pending.0.is_poisoned());
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn run_scoped_allows_borrowed_jobs() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let total = AtomicU64::new(0);
        {
            let data = &data;
            let total = &total;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|t| {
                    Box::new(move || {
                        let s: u64 = data.iter()
                            .skip(t)
                            .step_by(4)
                            .sum();
                        total.fetch_add(s, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn global_pool_is_shared_and_reusable() {
        for _ in 0..2 {
            let counter = AtomicU64::new(0);
            let c = &counter;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            global().run_scoped(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 8);
        }
    }

    #[test]
    fn run_scoped_waits_per_batch_not_pool_wide() {
        // A scoped batch must not convoy on another caller's jobs:
        // with a free worker available, the fast batch returns while
        // the slow batch is still running (the old pool-wide wait
        // blocked until *all* pending jobs drained).
        let pool = Arc::new(ThreadPool::new(3));
        let p2 = Arc::clone(&pool);
        let slow = thread::spawn(move || {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(|| {
                    thread::sleep(std::time::Duration::from_millis(300));
                })];
            p2.run_scoped(jobs);
        });
        // Let the slow job occupy its worker first.
        thread::sleep(std::time::Duration::from_millis(30));
        let t0 = std::time::Instant::now();
        let hit = AtomicU64::new(0);
        {
            let hit = &hit;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(move || {
                    hit.fetch_add(1, Ordering::Relaxed);
                })];
            pool.run_scoped(jobs);
        }
        let fast = t0.elapsed();
        assert_eq!(hit.load(Ordering::Relaxed), 1);
        assert!(fast < std::time::Duration::from_millis(150),
                "fast batch convoyed on the slow one: {fast:?}");
        slow.join().unwrap();
    }

    #[test]
    fn busy_nanos_accumulate_per_worker() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.busy_nanos(), vec![0, 0]);
        for _ in 0..8 {
            pool.submit(|| {
                thread::sleep(std::time::Duration::from_millis(2));
            });
        }
        pool.wait();
        let busy = pool.busy_nanos();
        assert_eq!(busy.len(), 2);
        // 8 x 2ms across 2 workers: total at least ~8ms even with
        // scheduling slop.
        assert!(busy.iter().sum::<u64>() >= 8_000_000,
                "busy nanos too low: {busy:?}");
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }
}
