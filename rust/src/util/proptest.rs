//! Lightweight property-testing harness (no proptest crate offline).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`;
//! the runner executes it across many derived seeds and reports the first
//! failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! check("loss is monotone", 200, |g| {
//!     let inst = Instance::random(g);
//!     ...
//!     ensure(cond, || format!("violated at {x}"))
//! });
//! ```

use super::prng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Size hint growing with the case index (small cases first, like
    /// classic QuickCheck sizing).
    pub fn size(&self, max: usize) -> usize {
        let lo = 2usize;
        let hi = max.max(lo + 1);
        lo + (self.case * (hi - lo)) / 100.max(self.case + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize_below(xs.len())]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_gaussian(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.gaussian_f32() * scale).collect()
    }
}

/// Helper for readable property bodies.
pub fn ensure<F: FnOnce() -> String>(cond: bool, msg: F)
    -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Run `prop` over `cases` derived seeds; panics with the failing seed.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xC0FFEE, prop)
}

pub fn check_seeded<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  {msg}\n  \
                 replay: check_seeded(\"{name}\", 1, {seed:#x}, ..)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            count += 0 * g.case; // silence unused
            Ok(())
        });
        let _ = count;
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            ensure(g.case < 5, || format!("case {} too big", g.case))
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("ranges", 100, |g| {
            let n = g.usize_in(3, 9);
            ensure((3..=9).contains(&n), || format!("{n}"))?;
            let x = g.f32_in(-1.0, 1.0);
            ensure((-1.0..1.0).contains(&x), || format!("{x}"))
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<usize> = Vec::new();
        check_seeded("record", 5, 42, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check_seeded("record", 5, 42, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
