//! Dense f32 matrix type used by the native pruning engine and the
//! parameter store.  Deliberately small: row-major storage, the handful
//! of BLAS-1/2/3 operations the algorithms need, no broadcasting.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.at(i, j);
            }
        }
        out
    }

    /// C = A * B  (ikj loop order for cache-friendly access).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, m);
        for i in 0..n {
            let arow = self.row(i);
            let orow = &mut out.data[i * m..(i + 1) * m];
            for (kk, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// G += X^T X for an activation block X ([t, d] row-major).
    pub fn gram_accumulate(&mut self, x: &Matrix) {
        assert_eq!(self.rows, x.cols);
        assert_eq!(self.cols, x.cols);
        let d = x.cols;
        for t in 0..x.rows {
            let xr = x.row(t);
            for i in 0..d {
                let xi = xr[i];
                if xi == 0.0 {
                    continue;
                }
                let grow = &mut self.data[i * d..(i + 1) * d];
                for j in 0..d {
                    grow[j] += xi * xr[j];
                }
            }
        }
    }

    pub fn diag(&self) -> Vec<f32> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i)).collect()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn frobenius_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled accumulation: measurably faster than a naive fold
    // and deterministic across runs.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let c = a.matmul(&Matrix::eye(4));
        assert_eq!(c, a);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_explicit() {
        let x = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let mut g = Matrix::zeros(2, 2);
        g.gram_accumulate(&x);
        let want = x.transpose().matmul(&x);
        assert!(g.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn gram_accumulates_incrementally() {
        let x1 = Matrix::from_fn(4, 3, |i, j| (i + j) as f32);
        let x2 = Matrix::from_fn(5, 3, |i, j| (i * j) as f32 - 1.0);
        let mut g = Matrix::zeros(3, 3);
        g.gram_accumulate(&x1);
        g.gram_accumulate(&x2);
        let mut whole = Matrix::zeros(3, 3);
        let mut cat = x1.data.clone();
        cat.extend_from_slice(&x2.data);
        whole.gram_accumulate(&Matrix::from_vec(9, 3, cat));
        assert!(g.max_abs_diff(&whole) < 1e-4);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5);
        let x = vec![1.0, -2.0, 0.5];
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(3, 1, x);
        let want = a.matmul(&xm);
        for i in 0..4 {
            assert!((y[i] - want.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.3 - 7.0).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32) * -0.1 + 2.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }
}
