//! Dense f32 matrix type used by the native pruning engine and the
//! parameter store, plus [`GramView`], the zero-copy view of a square
//! Gram matrix.  Deliberately small: row-major storage, the handful of
//! BLAS-1/2/3 operations the algorithms need, no broadcasting.  All
//! compute routes through the runtime-dispatched kernel layer
//! (`util::kernels`); the scalar arm reproduces the historic loops
//! bit-for-bit.

use std::fmt;

use crate::util::kernels;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Zero-copy [`GramView`] of this (square) matrix.
    pub fn as_gram(&self) -> GramView<'_> {
        assert_eq!(self.rows, self.cols, "gram view requires square");
        GramView::new(&self.data, self.rows)
    }

    /// Zero-copy [`MatrixView`] of this matrix.
    pub fn view(&self) -> MatrixView<'_> {
        MatrixView::new(&self.data, self.rows, self.cols)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.at(i, j);
            }
        }
        out
    }

    /// C = A * B through the kernel layer's cache-blocked, packed-panel
    /// multiply (scalar arm bit-identical to the historic ikj loop).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        kernels::matmul(self, other)
    }

    /// [`Self::matmul`] parallelised over output-row panels (the
    /// scheme `syrk` uses).  Bit-identical for every thread count.
    pub fn matmul_par(&self, other: &Matrix, threads: usize) -> Matrix {
        kernels::matmul_par(self, other, threads)
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// G += X^T X for an activation block X ([t, d] row-major), via the
    /// kernel layer's symmetric rank-k update (upper triangle +
    /// mirror).  `self` must be exactly symmetric on entry — zeros or
    /// a previous Gram accumulation.
    pub fn gram_accumulate(&mut self, x: &Matrix) {
        kernels::syrk_arm(kernels::active(), self, x, 1);
    }

    /// [`Self::gram_accumulate`] parallelised over row panels.  Results
    /// are bit-identical for every thread count.
    pub fn gram_accumulate_par(&mut self, x: &Matrix, threads: usize) {
        kernels::syrk_arm(kernels::active(), self, x, threads);
    }

    pub fn diag(&self) -> Vec<f32> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i)).collect()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn frobenius_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Borrowed, zero-copy view of a square Gram matrix: a `d * d` window
/// into a backing buffer (one layer's slice of a `[n_blocks, d, d]`
/// calibration stream stack, or a whole square [`Matrix`]) plus the
/// dimension.  `Copy`, so engines pass it by value; rows borrow from
/// the backing store and are never cloned.
#[derive(Clone, Copy, Debug)]
pub struct GramView<'a> {
    data: &'a [f32],
    /// Dimension (the view is d x d).
    pub d: usize,
}

impl<'a> GramView<'a> {
    pub fn new(data: &'a [f32], d: usize) -> GramView<'a> {
        assert_eq!(data.len(), d * d, "gram view must be d*d");
        GramView { data, d }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.d && j < self.d);
        self.data[i * self.d + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// The full contiguous d*d backing slice (row-major).
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    /// Diagonal, gathered into an owned vector (O(d), not O(d^2)).
    pub fn diag(&self) -> Vec<f32> {
        (0..self.d).map(|i| self.at(i, i)).collect()
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.d, x.len());
        (0..self.d).map(|i| dot(self.row(i), x)).collect()
    }

    /// Owned copy — only for callers that must outlive the backing
    /// store (snapshots, tests); the refinement path never needs it.
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.d, self.d, self.data.to_vec())
    }
}

impl<'a> From<&'a Matrix> for GramView<'a> {
    fn from(m: &'a Matrix) -> GramView<'a> {
        m.as_gram()
    }
}

/// Borrowed, zero-copy view of a rectangular row-major matrix: a
/// `rows * cols` window into a backing buffer (a [`Matrix`], or a
/// weight tensor leased from a `WeightStore` block).  `Copy`, so the
/// refiners pass it by value; rows borrow from the backing store and
/// are never cloned.
#[derive(Clone, Copy, Debug)]
pub struct MatrixView<'a> {
    data: &'a [f32],
    pub rows: usize,
    pub cols: usize,
}

impl<'a> MatrixView<'a> {
    pub fn new(data: &'a [f32], rows: usize, cols: usize) -> MatrixView<'a> {
        assert_eq!(data.len(), rows * cols, "matrix view must be rows*cols");
        MatrixView { data, rows, cols }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The full contiguous rows*cols backing slice (row-major).
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    /// Owned copy — only for callers that must outlive the backing
    /// store (snapshots, warm-start mutation); the saliency and swap
    /// paths never need it.
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

impl<'a> From<&'a Matrix> for MatrixView<'a> {
    fn from(m: &'a Matrix) -> MatrixView<'a> {
        m.view()
    }
}

/// Dot product (kernel-dispatched; scalar arm keeps the historic
/// 4-lane unrolled reduction, deterministic per arm).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

/// y += alpha * x (kernel-dispatched; elementwise mul+add in both
/// arms, so results are bit-identical across arms).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    kernels::axpy(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::kernels::Arm;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let c = a.matmul(&Matrix::eye(4));
        assert_eq!(c, a);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_explicit() {
        let x = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let mut g = Matrix::zeros(2, 2);
        g.gram_accumulate(&x);
        let want = x.transpose().matmul(&x);
        assert!(g.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn gram_accumulates_incrementally() {
        let x1 = Matrix::from_fn(4, 3, |i, j| (i + j) as f32);
        let x2 = Matrix::from_fn(5, 3, |i, j| (i * j) as f32 - 1.0);
        let mut g = Matrix::zeros(3, 3);
        g.gram_accumulate(&x1);
        g.gram_accumulate(&x2);
        let mut whole = Matrix::zeros(3, 3);
        let mut cat = x1.data.clone();
        cat.extend_from_slice(&x2.data);
        whole.gram_accumulate(&Matrix::from_vec(9, 3, cat));
        assert!(g.max_abs_diff(&whole) < 1e-4);
    }

    #[test]
    fn gram_par_is_bit_identical() {
        let mut rng = crate::util::prng::Rng::new(3);
        let x = Matrix::from_fn(30, 17, |_, _| rng.gaussian_f32());
        let mut g1 = Matrix::zeros(17, 17);
        g1.gram_accumulate(&x);
        let mut g4 = Matrix::zeros(17, 17);
        g4.gram_accumulate_par(&x, 4);
        for (a, b) in g1.data.iter().zip(&g4.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5);
        let x = vec![1.0, -2.0, 0.5];
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(3, 1, x);
        let want = a.matmul(&xm);
        for i in 0..4 {
            assert!((y[i] - want.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn dot_matches_naive_relative() {
        // Relative tolerance: the old absolute 1e-2 bound broke for
        // large-magnitude inputs.  Cover small, ragged and
        // large-magnitude vectors on every available arm.
        for (n, scale) in [(7usize, 1.0f32), (103, 1.0), (103, 1e6),
                           (1025, 3e4)] {
            let a: Vec<f32> = (0..n)
                .map(|i| ((i as f32) * 0.3 - 7.0) * scale)
                .collect();
            let b: Vec<f32> = (0..n)
                .map(|i| ((i as f32) * -0.1 + 2.0) * scale)
                .collect();
            let naive: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            for arm in crate::util::kernels::arms() {
                let got = crate::util::kernels::dot_arm(arm, &a, &b);
                let rel = (got as f64 - naive).abs()
                    / naive.abs().max(1e-12);
                assert!(rel < 1e-4,
                        "n={n} scale={scale} arm={arm:?}: {got} vs \
                         {naive} (rel {rel})");
            }
        }
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn gram_view_addresses_square() {
        let m = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let v = m.as_gram();
        assert_eq!(v.d, 3);
        assert_eq!(v.at(1, 2), 5.0);
        assert_eq!(v.row(2), &[6.0, 7.0, 8.0]);
        assert_eq!(v.diag(), vec![0.0, 4.0, 8.0]);
        assert_eq!(v.to_matrix(), m);
        assert_eq!(v.as_slice(), &m.data[..]);
    }

    #[test]
    fn gram_view_slices_a_stack() {
        // Two stacked 2x2 grams in one buffer; the view addresses the
        // second without copying.
        let stack = vec![0.0f32, 0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0];
        let v = GramView::new(&stack[4..8], 2);
        assert_eq!(v.at(0, 0), 1.0);
        assert_eq!(v.at(1, 1), 4.0);
        assert_eq!(v.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn matrix_view_addresses_rect() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let v = m.view();
        assert_eq!((v.rows, v.cols), (2, 3));
        assert_eq!(v.at(1, 2), 5.0);
        assert_eq!(v.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(v.as_slice(), &m.data[..]);
        assert_eq!(v.to_matrix(), m);
    }

    #[test]
    fn matrix_view_slices_a_stack() {
        // Two stacked 2x2 tensors in one buffer; the view addresses
        // the second without copying.
        let stack = vec![0.0f32, 0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0];
        let v = MatrixView::new(&stack[4..8], 2, 2);
        assert_eq!(v.at(0, 1), 2.0);
        assert_eq!(v.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn matmul_scalar_arm_exact_shapes() {
        // Blocked path crosses the KC/NC boundaries; values must still
        // match a naive product.
        let mut rng = crate::util::prng::Rng::new(4);
        let a = Matrix::from_fn(3, 130, |_, _| rng.gaussian_f32());
        let b = Matrix::from_fn(130, 10, |_, _| rng.gaussian_f32());
        let got = crate::util::kernels::matmul_arm(Arm::Scalar, &a, &b);
        let mut want = Matrix::zeros(3, 10);
        for i in 0..3 {
            for j in 0..10 {
                let mut s = 0.0f64;
                for k in 0..130 {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                want.set(i, j, s as f32);
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-3);
    }
}
