//! Minimal JSON parser / emitter (no serde available offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! kept as `f64` (the manifest only stores small integers and floats).
//! Used for `artifacts/manifest.json`, experiment reports, and metric
//! dumps.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted-path lookup.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- construction helpers ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact serialisation.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our data;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(),
                   Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {} }"#)
            .unwrap();
        assert_eq!(v.path("a").unwrap().at(2).unwrap().path("b").unwrap(),
                   &Json::Str("c".into()));
        assert_eq!(v.get("d").unwrap(), &Json::Obj(Default::default()));
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"dims":[4,8],"dtype":"float32"}"#,
            r#"[1,2.5,"x",null,true,[]]"#,
            r#"{"nested":{"deep":{"val":-3}}}"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{c}");
        }
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"open", "tru", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"ünï→\"").unwrap();
        assert_eq!(v, Json::Str("ünï→".into()));
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(),
                   Json::Str("é".into()));
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::Num(4.0).as_usize(), Some(4));
        assert_eq!(Json::Num(4.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
