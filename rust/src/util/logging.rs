//! Tiny leveled logger with elapsed-time stamps (no log/env_logger
//! offline).  Verbosity comes from `SPARSESWAPS_LOG` (error|warn|info|
//! debug) or `set_level`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> &'static Instant {
    START.get_or_init(Instant::now)
}

pub fn init_from_env() {
    let lvl = match std::env::var("SPARSESWAPS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = start();
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:>8.3}s {}] {}", t.as_secs_f64(), tag, args);
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
