//! Substrate utilities built in-repo (the offline environment provides
//! no serde/clap/tokio/criterion/proptest/rayon): JSON, CLI parsing,
//! threading, PRNG, property testing, benchmarking, dense tensors,
//! logging.

pub mod benchlib;
pub mod cli;
pub mod jsonlite;
pub mod kernels;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod tensor;
pub mod threadpool;
