//! SparseSwaps: tractable LLM pruning mask refinement at scale.
//!
//! Reproduction of Zimmer et al. (2025) as a three-layer Rust + JAX +
//! Pallas system: Pallas kernels (L1) and JAX graphs (L2) are AOT-lowered
//! to HLO text at build time; this crate (L3) loads them through PJRT and
//! owns the entire pruning pipeline — training, calibration, warmstarts,
//! 1-swap refinement, evaluation and reporting.  See DESIGN.md.

pub mod util;
pub mod pruning;
pub mod runtime;
pub mod model;
pub mod tokenizer;
pub mod data;
pub mod gram;
pub mod eval;
pub mod coordinator;
pub mod report;
