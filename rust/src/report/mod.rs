//! Experiment grids regenerating every table and figure of the paper's
//! evaluation section (see DESIGN.md section 4 for the index).
//!
//! Absolute numbers differ from the paper (tiny models, synthetic data,
//! CPU PJRT — DESIGN.md section 2); the *shapes* are what each function
//! asserts and reports: who wins, in which regime, and by roughly what
//! factor.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::{
    train, MaskSpec, PatternKind, PruneReport, PruneSession, Refiner,
    RunOptions, TrainConfig,
};
use crate::data::{Dataset, Split};
use crate::eval::{perplexity_pool, zeroshot};
use crate::model::checkpoint;
use crate::model::store::{MaskSet, ParamStore};
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{RuntimeError, RuntimeOptions};
use crate::util::benchlib::{ascii_plot, Table};

/// Shared context: runtime pool + trained-model cache.  `rt` derefs
/// to the pool's primary runtime, so serial call sites are unchanged;
/// `prune` fans offload layers out across all pool workers.
pub struct Ctx {
    pub rt: RuntimePool,
    pub runs_dir: PathBuf,
    /// Quick mode: tiny model, smaller budgets (CI-friendly).
    pub quick: bool,
    cache: std::sync::Mutex<BTreeMap<String, (ParamStore, u64)>>,
}

impl Ctx {
    pub fn new(rt: RuntimePool, runs_dir: impl Into<PathBuf>, quick: bool)
        -> Ctx {
        Ctx { rt, runs_dir: runs_dir.into(), quick,
              cache: std::sync::Mutex::new(BTreeMap::new()) }
    }

    pub fn from_env() -> Result<Ctx, RuntimeError> {
        let dir = std::env::var("SPARSESWAPS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into());
        let devices = std::env::var("SPARSESWAPS_DEVICES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        let rt = RuntimePool::start(&dir, devices,
                                    RuntimeOptions::default())?;
        let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
        Ok(Ctx::new(rt, "runs", quick))
    }

    /// The model zoo standing in for the paper's five LLM families.
    pub fn zoo(&self) -> Vec<String> {
        if self.quick {
            vec!["tiny".into()]
        } else {
            ["gpt-a", "gpt-b", "gpt-c"]
                .iter()
                .filter(|n| self.rt.manifest().configs.contains_key(**n))
                .map(|s| s.to_string())
                .collect()
        }
    }

    pub fn train_steps(&self) -> usize {
        if self.quick { 60 } else { 150 }
    }

    pub fn calib_batches(&self) -> usize {
        if self.quick { 3 } else { 4 }
    }

    pub fn t_max(&self) -> usize {
        if self.quick { 10 } else { 25 }
    }

    pub fn val_batches(&self) -> usize {
        if self.quick { 3 } else { 6 }
    }

    pub fn dataset(&self, name: &str) -> Result<Dataset, RuntimeError> {
        let meta = self.rt.manifest().config(name)?.clone();
        Ok(Dataset::build(&meta, 42 ^ meta.init_seed))
    }

    /// Train (or load a cached checkpoint of) a zoo model.
    pub fn model(&self, name: &str)
        -> Result<(ParamStore, Dataset), RuntimeError> {
        let meta = self.rt.manifest().config(name)?.clone();
        let ds = self.dataset(name)?;
        if let Some((store, _)) = self.cache.lock().unwrap().get(name) {
            return Ok((store.clone(), ds));
        }
        let steps = self.train_steps();
        let path = self.runs_dir.join(format!("{name}-s{steps}.ssck"));
        let store = match checkpoint::load(&path, &meta) {
            Ok((store, _)) => {
                crate::log_info!("loaded cached checkpoint {}",
                                 path.display());
                store
            }
            Err(_) => {
                crate::log_info!("training {name} for {steps} steps");
                let mut store = ParamStore::init(&meta, meta.init_seed);
                let cfg = TrainConfig { steps, lr: 2e-3, n_batches: 24,
                                        log_every: 50 };
                train(&self.rt, &mut store, &ds, &cfg)?;
                checkpoint::save(&path, &store, None)
                    .map_err(|e| RuntimeError::Msg(e.to_string()))?;
                store
            }
        };
        self.cache.lock().unwrap()
            .insert(name.to_string(), (store.clone(), 0));
        Ok((store, ds))
    }

    fn base_spec(&self) -> MaskSpec {
        MaskSpec {
            t_max: self.t_max(),
            calib_batches: self.calib_batches(),
            sequential: false, // shared grams across method comparisons
            ..Default::default()
        }
    }

    /// One-off prune through a fresh `PruneSession`.  Grid cells that
    /// touch a model once go through here; chains of specs on one
    /// model build their own session so the dense calibration pass is
    /// shared.  Layer-parallel scheduling (the `RunOptions` default)
    /// is mask-identical to serial — a pipeline invariant — so the
    /// experiment grids keep it on.
    fn prune(&self, store: &ParamStore, ds: &Dataset, spec: &MaskSpec)
        -> Result<(MaskSet, PruneReport), RuntimeError> {
        PruneSession::new(&self.rt, store, ds, RunOptions::default())
            .prune(spec)
    }

    fn eval_model(&self, store: &ParamStore, ds: &Dataset,
                  masks: Option<&MaskSet>)
        -> Result<(f64, f64), RuntimeError> {
        let masked;
        let target = match masks {
            Some(m) => {
                masked = store.masked(m);
                &masked
            }
            None => store,
        };
        let val = ds.batches(&store.meta, Split::Validation,
                             self.val_batches());
        let ppl = perplexity_pool(&self.rt, target, &val)?;
        let n_tasks = if self.quick { 24 } else { 64 };
        let tasks = zeroshot::build_tasks(ds, store.meta.vocab, n_tasks,
                                          911);
        let acc = zeroshot::accuracy_pool(&self.rt, target, &tasks)?;
        Ok((ppl, acc))
    }
}

fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

// --- Table 1 ----------------------------------------------------------------

/// Table 1: ppl + zero-shot for {Wanda, RIA} x {none, DSnoT, SparseSwaps}
/// at 60% row-wise and 2:4 sparsity, across the zoo.
pub fn table1(ctx: &Ctx) -> Result<(Table, Table), RuntimeError> {
    use crate::pruning::Criterion;
    let zoo = ctx.zoo();
    let mut headers: Vec<String> = vec!["Method".into(),
                                        "Sparsity".into()];
    headers.extend(zoo.iter().cloned());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t_ppl = Table::new(
        "Table 1a — Perplexity (lower is better)", &hdr);
    let mut t_acc = Table::new(
        "Table 1b — Zero-shot accuracy (higher is better)", &hdr);

    let patterns = [PatternKind::Unstructured { sparsity: 0.6 },
                    PatternKind::Nm { n: 2, m: 4 }];
    let methods: Vec<(&str, Criterion, Refiner)> = vec![
        ("Wanda", Criterion::Wanda, Refiner::None),
        ("+ DSnoT", Criterion::Wanda, Refiner::Dsnot),
        ("+ SparseSwaps", Criterion::Wanda, Refiner::SparseSwapsNative),
        ("RIA", Criterion::Ria, Refiner::None),
        ("+ DSnoT", Criterion::Ria, Refiner::Dsnot),
        ("+ SparseSwaps", Criterion::Ria, Refiner::SparseSwapsNative),
    ];

    for pattern in patterns {
        for (label, crit, refiner) in &methods {
            let mut ppl_row = vec![label.to_string(), pattern.label()];
            let mut acc_row = vec![label.to_string(), pattern.label()];
            for name in &zoo {
                let (store, ds) = ctx.model(name)?;
                let spec = MaskSpec {
                    criterion: *crit,
                    pattern_kind: pattern,
                    refiner: refiner.clone(),
                    ..ctx.base_spec()
                };
                let (masks, _) = ctx.prune(&store, &ds, &spec)?;
                let (ppl, acc) = ctx.eval_model(&store, &ds,
                                                Some(&masks))?;
                ppl_row.push(format!("{ppl:.2}"));
                acc_row.push(pct(acc));
            }
            t_ppl.row(ppl_row);
            t_acc.row(acc_row);
        }
    }
    Ok((t_ppl, t_acc))
}

// --- Table 2 ----------------------------------------------------------------

/// Table 2: magnitude warmstart at 50% / 60%, with and without
/// SparseSwaps — the high-degradation regime where refinement helps most.
pub fn table2(ctx: &Ctx) -> Result<Table, RuntimeError> {
    use crate::pruning::Criterion;
    let zoo = ctx.zoo();
    let mut headers: Vec<String> = vec!["Method".into(),
                                        "Sparsity".into()];
    headers.extend(zoo.iter().cloned());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 2 — Perplexity, magnitude warmstart", &hdr);
    for sparsity in [0.5, 0.6] {
        for (label, refiner) in [
            ("Magnitude", Refiner::None),
            ("+ SparseSwaps",
             Refiner::SparseSwapsNative),
        ] {
            let mut row = vec![label.to_string(),
                               format!("{:.0}%", sparsity * 100.0)];
            for name in &zoo {
                let (store, ds) = ctx.model(name)?;
                let spec = MaskSpec {
                    criterion: Criterion::Magnitude,
                    pattern_kind:
                        PatternKind::Unstructured { sparsity },
                    refiner: refiner.clone(),
                    ..ctx.base_spec()
                };
                let (masks, _) = ctx.prune(&store, &ds, &spec)?;
                let (ppl, _) = ctx.eval_model(&store, &ds, Some(&masks))?;
                row.push(format!("{ppl:.2}"));
            }
            t.row(row);
        }
    }
    Ok(t)
}

// --- Table 3 ----------------------------------------------------------------

/// Table 3: mean relative error reduction and perplexity vs the number
/// of 1-swap iterations (Wanda warmstart; 50% and 60% sparsity).
pub fn table3(ctx: &Ctx, model: &str)
    -> Result<Table, RuntimeError> {
    let iters: Vec<usize> = if ctx.quick {
        vec![1, 2, 5, 10]
    } else {
        vec![1, 2, 5, 10, 25, 50]
    };
    let mut headers: Vec<String> = vec!["Sparsity".into(),
                                        "Metric".into(), "0".into()];
    headers.extend(iters.iter().map(|i| i.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Table 3 — error reduction & ppl vs iterations ({model})"),
        &hdr);

    let (store, ds) = ctx.model(model)?;
    // All four runs share one calibration pass through the session.
    let mut session = PruneSession::new(&ctx.rt, &store, &ds,
                                        RunOptions::default());
    for sparsity in [0.5, 0.6] {
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity },
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            t_max: *iters.last().unwrap(),
            checkpoints: iters.clone(),
            ..ctx.base_spec()
        };
        // Warmstart-only run for the 0-iteration column.
        let spec0 = MaskSpec { refiner: Refiner::None,
                               checkpoints: vec![], ..spec.clone() };
        let (masks0, rep0) = session.prune(&spec0)?;
        let (ppl0, _) = ctx.eval_model(&store, &ds, Some(&masks0))?;
        let base_losses: Vec<f64> = rep0.layers.iter()
            .map(|l| l.loss_warmstart).collect();

        let (_, rep) = session.prune(&spec)?;
        let mut err_row = vec![format!("{:.0}%", sparsity * 100.0),
                               "Error reduction (%)".to_string(),
                               "0.00".to_string()];
        let mut ppl_row = vec![format!("{:.0}%", sparsity * 100.0),
                               "Perplexity".to_string(),
                               format!("{ppl0:.2}")];
        for &it in &iters {
            let snap = &rep.snapshots[&it];
            // Mean per-layer relative reduction vs warmstart, recomputed
            // exactly (native Gram-form loss) under the snapshot mask.
            let red = checkpoint_reductions(ctx, &store, &ds, &spec,
                                            snap, &base_losses)?;
            err_row.push(format!("{:.2}", 100.0 * red));
            let (ppl, _) = ctx.eval_model(&store, &ds, Some(snap))?;
            ppl_row.push(format!("{ppl:.2}"));
        }
        t.row(err_row);
        t.row(ppl_row);
    }
    Ok(t)
}

/// Mean per-layer relative error reduction of `snap` vs warmstart
/// losses, recomputed exactly from fresh gram statistics.
fn checkpoint_reductions(ctx: &Ctx, store: &ParamStore, ds: &Dataset,
                         spec: &MaskSpec, snap: &MaskSet,
                         base_losses: &[f64])
    -> Result<f64, RuntimeError> {
    let calib = ds.batches(&store.meta, Split::Calibration,
                           spec.calib_batches);
    let stats = crate::gram::accumulate(&ctx.rt, store, &calib)?;
    let mut total = 0.0;
    let n = store.meta.prunable.len();
    for (li, layer) in store.meta.prunable.iter().enumerate() {
        let w = store.weight(layer);
        let g = stats.gram_for(layer);
        let after = crate::pruning::error::layer_loss(
            w, &snap.masks[li], g);
        total += crate::pruning::error::relative_reduction(
            base_losses[li], after);
    }
    Ok(total / n as f64)
}

// --- Table 4 ----------------------------------------------------------------

/// Table 4: average relative error reduction per warmstart criterion —
/// weaker warmstarts leave more room (magnitude > wanda).
pub fn table4(ctx: &Ctx) -> Result<Table, RuntimeError> {
    use crate::pruning::Criterion;
    let zoo = ctx.zoo();
    let mut headers: Vec<String> = vec!["Warmstart".into()];
    headers.extend(zoo.iter().cloned());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 4 — mean relative error reduction at 60% sparsity", &hdr);
    for (label, crit) in [("Magnitude", Criterion::Magnitude),
                          ("Wanda", Criterion::Wanda)] {
        let mut row = vec![label.to_string()];
        for name in &zoo {
            let (store, ds) = ctx.model(name)?;
            let spec = MaskSpec {
                criterion: crit,
                pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
                refiner: Refiner::SparseSwapsOffload {
                    impl_name: "xla".into(),
                },
                ..ctx.base_spec()
            };
            let (_, rep) = ctx.prune(&store, &ds, &spec)?;
            row.push(pct(rep.mean_relative_reduction()));
        }
        t.row(row);
    }
    Ok(t)
}

// --- Table 5 ----------------------------------------------------------------

/// Table 5: wall-clock of the pipeline vs T_max (the linear-overhead
/// claim).  T_max = 0 is the baseline: calibration + Wanda + evaluation.
pub fn table5(ctx: &Ctx, model: &str) -> Result<Table, RuntimeError> {
    let tmaxes: Vec<usize> = if ctx.quick {
        vec![0, 1, 2, 5]
    } else {
        vec![0, 1, 2, 5, 10, 25]
    };
    let mut headers: Vec<String> = vec!["T_max".into()];
    headers.extend(tmaxes.iter().map(|t| t.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Table 5 — wall-clock seconds vs T_max ({model})"), &hdr);
    let (store, ds) = ctx.model(model)?;
    let mut row = vec!["seconds".to_string()];
    for &tm in &tmaxes {
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: if tm == 0 { Refiner::None } else {
                Refiner::SparseSwapsNative
            },
            // Engines handle t_max == 0 gracefully now; no .max(1)
            // workaround needed.
            t_max: tm,
            ..ctx.base_spec()
        };
        let t0 = Instant::now();
        // Fresh session per point: each column times the *full*
        // pipeline (calibration included), as the paper's linear-
        // overhead claim is about end-to-end wall-clock.
        let (masks, _) = ctx.prune(&store, &ds, &spec)?;
        let _ = ctx.eval_model(&store, &ds, Some(&masks))?;
        row.push(format!("{:.1}", t0.elapsed().as_secs_f64()));
    }
    t.row(row);
    Ok(t)
}

// --- Figure 1 ----------------------------------------------------------------

/// Figure 1: per-layer relative error reduction vs Wanda, grouped by
/// transformer block and layer type.
pub fn fig1(ctx: &Ctx, model: &str)
    -> Result<(Table, String), RuntimeError> {
    let (store, ds) = ctx.model(model)?;
    let spec = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::SparseSwapsNative,
        ..ctx.base_spec()
    };
    let (_, rep) = ctx.prune(&store, &ds, &spec)?;

    let layer_types = ["attn.q_proj", "attn.k_proj", "attn.v_proj",
                       "attn.o_proj", "mlp.gate_proj", "mlp.up_proj",
                       "mlp.down_proj"];
    let n_blocks = store.meta.n_blocks;
    let mut headers = vec!["Layer type".to_string()];
    headers.extend((0..n_blocks).map(|b| format!("block {b}")));
    headers.push("mean".into());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Figure 1 — per-layer error reduction vs Wanda ({model}, \
                 60%)"), &hdr);
    let mut series = Vec::new();
    for lt in layer_types {
        let mut row = vec![lt.to_string()];
        let mut vals = Vec::new();
        for b in 0..n_blocks {
            let l = rep.layers.iter()
                .find(|l| l.layer_type == lt && l.block == b)
                .expect("layer present");
            let red = l.relative_reduction();
            row.push(pct(red));
            vals.push(100.0 * red);
        }
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        row.push(format!("{mean:.2}%"));
        t.row(row);
        series.push((lt, vals));
    }
    let xs: Vec<f64> = (0..n_blocks).map(|b| b as f64).collect();
    let series_ref: Vec<(&str, Vec<f64>)> = series.iter()
        .map(|(n, v)| (*n, v.clone())).collect();
    let plot = ascii_plot(
        "Figure 1 — relative error reduction (%) by block", &xs,
        &series_ref, 60, 12);
    Ok((t, plot))
}

// --- Figure 2 ----------------------------------------------------------------

/// Figure 2: perplexity vs the number of calibration batches, Wanda vs
/// Wanda + SparseSwaps, at 50% and 60% sparsity.
pub fn fig2(ctx: &Ctx, model: &str)
    -> Result<(Table, String), RuntimeError> {
    let sample_counts: Vec<usize> = if ctx.quick {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 4, 8, 16]
    };
    let (store, ds) = ctx.model(model)?;
    let mut headers = vec!["Method".to_string(), "Sparsity".into()];
    headers.extend(sample_counts.iter().map(|c| format!("{c} batches")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Figure 2 — ppl vs calibration batches ({model})"), &hdr);
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for sparsity in [0.5, 0.6] {
        for (label, refiner) in [
            ("Wanda", Refiner::None),
            ("Wanda+SS", Refiner::SparseSwapsNative),
        ] {
            let mut row = vec![label.to_string(),
                               format!("{:.0}%", sparsity * 100.0)];
            let mut vals = Vec::new();
            for &n in &sample_counts {
                let spec = MaskSpec {
                    pattern_kind:
                        PatternKind::Unstructured { sparsity },
                    refiner: refiner.clone(),
                    calib_batches: n,
                    ..ctx.base_spec()
                };
                let (masks, _) = ctx.prune(&store, &ds, &spec)?;
                let (ppl, _) = ctx.eval_model(&store, &ds, Some(&masks))?;
                row.push(format!("{ppl:.2}"));
                vals.push(ppl);
            }
            t.row(row);
            series.push((format!("{label}@{:.0}%", sparsity * 100.0),
                         vals));
        }
    }
    let xs: Vec<f64> = sample_counts.iter().map(|&c| c as f64).collect();
    let series_ref: Vec<(&str, Vec<f64>)> = series.iter()
        .map(|(n, v)| (n.as_str(), v.clone())).collect();
    let plot = ascii_plot("Figure 2 — perplexity vs calibration batches",
                          &xs, &series_ref, 60, 12);
    Ok((t, plot))
}

#[cfg(test)]
mod tests {
    #[test]
    fn pct_formatting() {
        assert_eq!(super::pct(0.4321), "43.21%");
    }
}
