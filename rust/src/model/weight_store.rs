//! Block-granular weight access: the [`WeightStore`] trait.
//!
//! The paper's layer-wise mask selection only ever needs one
//! transformer block's weights resident at a time, so the pipeline
//! talks to parameters through block **leases** instead of a flat
//! in-memory tensor list:
//!
//! * [`ResidentStore`] (= [`ParamStore`]) serves leases as free `Arc`
//!   clones of its in-memory tensors — the behaviour every existing
//!   caller had, unchanged.
//! * [`StreamingStore`] backs tensors with the on-disk `.ssck`
//!   checkpoint: a lease faults the block's nine tensors in from disk,
//!   `release_block` drops them, and [`StoreStats`] keeps byte-accurate
//!   residency accounting against the `--host-mem-budget` flag.
//!
//! Leases hand out zero-copy [`MatrixView`]s, so refinement borrows
//! weight rows straight out of the lease for exactly the block's
//! lifetime — the same invariant `GramStats` now enforces for Gram
//! borrows.

use std::sync::{Arc, Mutex};

use crate::model::checkpoint::{CheckpointError, CheckpointReader};
use crate::model::store::{MaskSet, ParamStore};
use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::MatrixView;

#[derive(Debug)]
pub enum StoreError {
    Checkpoint(CheckpointError),
    /// Leasing would push accounted residency past `--host-mem-budget`.
    OverBudget { needed: usize, resident: usize, budget: usize },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Checkpoint(e) => write!(f, "checkpoint: {e}"),
            StoreError::OverBudget { needed, resident, budget } => write!(
                f,
                "host memory budget exceeded: lease of {needed} B on \
                 top of {resident} B resident would pass the budget of \
                 {budget} B (raise --host-mem-budget)"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Checkpoint(e) => Some(e),
            StoreError::OverBudget { .. } => None,
        }
    }
}

impl From<CheckpointError> for StoreError {
    fn from(e: CheckpointError) -> Self {
        StoreError::Checkpoint(e)
    }
}

/// Byte-accurate residency accounting for a [`WeightStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Bytes of parameter data the store currently holds resident.
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` over the store's lifetime.
    pub peak_bytes: usize,
    /// Tensors faulted in from disk (0 for a resident store).
    pub loads: usize,
    /// Total bytes read from disk across all loads.
    pub loaded_bytes: usize,
    /// `release_block`/`release_globals` calls that actually freed data.
    pub releases: usize,
    /// Residency budget in bytes (0 = unlimited).
    pub budget: usize,
}

/// A leased span of manifest tensors: one transformer block's nine
/// parameters, or the three globals (token embedding, final norm, LM
/// head).  Holding the lease keeps the tensors alive; views borrowed
/// from it must end before the block is released.
pub struct BlockLease {
    /// `(manifest param index, tensor)` pairs, ascending index.
    tensors: Vec<(usize, Arc<TensorData>)>,
}

impl BlockLease {
    fn new(tensors: Vec<(usize, Arc<TensorData>)>) -> BlockLease {
        BlockLease { tensors }
    }

    pub fn tensor(&self, param_index: usize) -> &TensorData {
        self.arc(param_index).as_ref()
    }

    pub fn arc(&self, param_index: usize) -> &Arc<TensorData> {
        self.tensors.iter()
            .find(|(i, _)| *i == param_index)
            .map(|(_, t)| t)
            .unwrap_or_else(|| panic!(
                "param {param_index} is not part of this lease"))
    }

    /// Zero-copy weight view of a prunable layer inside this lease.
    pub fn weight(&self, layer: &PrunableLayer) -> MatrixView<'_> {
        MatrixView::new(
            self.tensor(layer.param_index).as_f32()
                .expect("weights are f32"),
            layer.d_out, layer.d_in)
    }

    pub fn byte_size(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.byte_size()).sum()
    }

    /// Block `b`'s nine tensors in manifest order — the `calib_block`
    /// input prefix — with prunable weights masked (W ⊙ M) when
    /// `masks` is given (sequential-mode stream pushes).
    pub fn block_params(&self, meta: &ModelMeta, b: usize,
                        masks: Option<&MaskSet>) -> Vec<TensorData> {
        block_range(meta, b).map(|i| {
            let t = self.tensor(i);
            if let Some(ms) = masks {
                if let Some(li) = meta.prunable.iter()
                    .position(|l| l.param_index == i) {
                    let data = t.as_f32().expect("weights are f32")
                        .iter().zip(&ms.masks[li].data)
                        .map(|(&v, &m)| v * m)
                        .collect();
                    return TensorData::F32 {
                        dims: t.dims().to_vec(),
                        data,
                    };
                }
            }
            t.clone()
        }).collect()
    }
}

fn block_range(meta: &ModelMeta, b: usize) -> std::ops::Range<usize> {
    assert!(b < meta.n_blocks,
            "block {b} out of range ({} blocks)", meta.n_blocks);
    (1 + b * 9)..(1 + (b + 1) * 9)
}

fn global_indices(meta: &ModelMeta) -> [usize; 3] {
    let i_final_norm = 1 + meta.n_blocks * 9;
    [0, i_final_norm, i_final_norm + 1]
}

/// Block-granular access to model parameters.  `Sync` so a prefetch
/// stage can lease block `b+1` while block `b` refines.
pub trait WeightStore: Sync {
    fn meta(&self) -> &ModelMeta;

    /// Lease one transformer block's nine parameter tensors.
    fn lease_block(&self, b: usize) -> Result<BlockLease, StoreError>;

    /// Lease the token embedding, final norm and LM head.
    fn lease_globals(&self) -> Result<BlockLease, StoreError>;

    /// Drop the store's resident copy of block `b` (no-op when the
    /// store is resident anyway).  Outstanding leases stay valid; the
    /// next `lease_block(b)` faults the data back in.
    fn release_block(&self, _b: usize) {}

    fn release_globals(&self) {}

    fn stats(&self) -> StoreStats;

    /// True when tensors live out of core and residency is bounded by
    /// leases rather than the checkpoint size.
    fn out_of_core(&self) -> bool {
        false
    }

    /// The full in-memory store, when this is a resident store.
    fn as_resident(&self) -> Option<&ParamStore> {
        None
    }
}

/// Today's in-memory store is the resident implementation: leases are
/// `Arc` clones, releases are no-ops, and the whole parameter set
/// counts as permanently resident.
pub type ResidentStore = ParamStore;

impl WeightStore for ParamStore {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn lease_block(&self, b: usize) -> Result<BlockLease, StoreError> {
        Ok(BlockLease::new(block_range(&self.meta, b)
            .map(|i| (i, self.tensors[i].clone()))
            .collect()))
    }

    fn lease_globals(&self) -> Result<BlockLease, StoreError> {
        Ok(BlockLease::new(global_indices(&self.meta).iter()
            .map(|&i| (i, self.tensors[i].clone()))
            .collect()))
    }

    fn stats(&self) -> StoreStats {
        let bytes: usize =
            self.tensors.iter().map(|t| t.byte_size()).sum();
        StoreStats {
            resident_bytes: bytes,
            peak_bytes: bytes,
            ..StoreStats::default()
        }
    }

    fn as_resident(&self) -> Option<&ParamStore> {
        Some(self)
    }
}

struct StreamState {
    /// Faulted-in tensors per block (index = block).
    blocks: Vec<Option<Vec<Arc<TensorData>>>>,
    globals: Option<Vec<Arc<TensorData>>>,
    stats: StoreStats,
}

/// Out-of-core store backed by a validated `.ssck` checkpoint: every
/// lease faults its tensors in from disk (once, until released), so
/// peak host memory follows the lease pattern — O(2 blocks) under the
/// staged pipeline — instead of the checkpoint size.
pub struct StreamingStore {
    reader: CheckpointReader,
    state: Mutex<StreamState>,
}

impl StreamingStore {
    /// Open a checkpoint for streaming.  `budget_bytes` caps accounted
    /// residency (0 = unlimited); a lease that would pass it fails
    /// with [`StoreError::OverBudget`] instead of loading.
    pub fn open(path: impl AsRef<std::path::Path>, meta: &ModelMeta,
                budget_bytes: usize)
        -> Result<StreamingStore, StoreError> {
        let reader = CheckpointReader::open(path, meta)?;
        let n_blocks = meta.n_blocks;
        Ok(StreamingStore {
            reader,
            state: Mutex::new(StreamState {
                blocks: (0..n_blocks).map(|_| None).collect(),
                globals: None,
                stats: StoreStats {
                    budget: budget_bytes,
                    ..StoreStats::default()
                },
            }),
        })
    }

    /// Masks stored alongside the checkpoint params, if any.
    pub fn masks(&self) -> Option<&MaskSet> {
        self.reader.masks()
    }

    fn lease_indices(&self, indices: &[usize])
        -> Result<Vec<Arc<TensorData>>, StoreError> {
        let meta = &self.reader.meta;
        let needed: usize = indices.iter()
            .map(|&i| {
                let n: usize = meta.params[i].1.iter().product();
                n * 4
            })
            .sum();
        let stats = {
            let st = self.state.lock().unwrap();
            st.stats
        };
        if stats.budget > 0
            && stats.resident_bytes + needed > stats.budget {
            return Err(StoreError::OverBudget {
                needed,
                resident: stats.resident_bytes,
                budget: stats.budget,
            });
        }
        // Disk reads happen outside the lock; the racing prefetcher
        // and refiner lease different blocks, so double-loading is not
        // a correctness concern and the budget check above is the only
        // gate.
        let tensors = indices.iter()
            .map(|&i| self.reader.load_tensor(i).map(Arc::new))
            .collect::<Result<Vec<_>, _>>()?;
        let mut st = self.state.lock().unwrap();
        st.stats.loads += tensors.len();
        st.stats.loaded_bytes += needed;
        st.stats.resident_bytes += needed;
        st.stats.peak_bytes =
            st.stats.peak_bytes.max(st.stats.resident_bytes);
        Ok(tensors)
    }

    fn release_entry(&self, slot: fn(&mut StreamState)
                                     -> &mut Option<Vec<Arc<TensorData>>>) {
        let mut st = self.state.lock().unwrap();
        if let Some(tensors) = slot(&mut st).take() {
            let bytes: usize =
                tensors.iter().map(|t| t.byte_size()).sum();
            st.stats.resident_bytes -= bytes;
            st.stats.releases += 1;
        }
    }
}

impl WeightStore for StreamingStore {
    fn meta(&self) -> &ModelMeta {
        &self.reader.meta
    }

    fn lease_block(&self, b: usize) -> Result<BlockLease, StoreError> {
        let indices: Vec<usize> =
            block_range(&self.reader.meta, b).collect();
        {
            let st = self.state.lock().unwrap();
            if let Some(cached) = &st.blocks[b] {
                return Ok(BlockLease::new(
                    indices.iter().copied()
                        .zip(cached.iter().cloned())
                        .collect()));
            }
        }
        let tensors = self.lease_indices(&indices)?;
        let lease = BlockLease::new(
            indices.iter().copied().zip(tensors.iter().cloned())
                .collect());
        self.state.lock().unwrap().blocks[b] = Some(tensors);
        Ok(lease)
    }

    fn lease_globals(&self) -> Result<BlockLease, StoreError> {
        let indices = global_indices(&self.reader.meta);
        {
            let st = self.state.lock().unwrap();
            if let Some(cached) = &st.globals {
                return Ok(BlockLease::new(
                    indices.iter().copied()
                        .zip(cached.iter().cloned())
                        .collect()));
            }
        }
        let tensors = self.lease_indices(&indices)?;
        let lease = BlockLease::new(
            indices.iter().copied().zip(tensors.iter().cloned())
                .collect());
        self.state.lock().unwrap().globals = Some(tensors);
        Ok(lease)
    }

    fn release_block(&self, b: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(tensors) = st.blocks[b].take() {
            let bytes: usize =
                tensors.iter().map(|t| t.byte_size()).sum();
            st.stats.resident_bytes -= bytes;
            st.stats.releases += 1;
        }
    }

    fn release_globals(&self) {
        self.release_entry(|st| &mut st.globals);
    }

    fn stats(&self) -> StoreStats {
        self.state.lock().unwrap().stats
    }

    fn out_of_core(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::checkpoint;
    use crate::model::testutil::tiny_meta;

    fn saved_store(tag: &str) -> (ModelMeta, ParamStore,
                                  std::path::PathBuf) {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir()
            .join(format!("ssck_ws_{tag}.ssck"));
        checkpoint::save(&path, &store, None).unwrap();
        (meta, store, path)
    }

    #[test]
    fn resident_leases_share_tensors() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let lease = store.lease_block(0).unwrap();
        for i in 1..10 {
            assert!(Arc::ptr_eq(lease.arc(i), &store.tensors[i]));
        }
        let globals = store.lease_globals().unwrap();
        assert!(Arc::ptr_eq(globals.arc(0), &store.tensors[0]));
        assert!(!store.out_of_core());
        assert!(store.as_resident().is_some());
        let stats = store.stats();
        assert_eq!(stats.loads, 0);
        assert_eq!(stats.resident_bytes,
                   store.tensors.iter()
                       .map(|t| t.byte_size())
                       .sum::<usize>());
    }

    #[test]
    fn streaming_lease_matches_resident_bitwise() {
        let (meta, store, path) = saved_store("bits");
        let ss = StreamingStore::open(&path, &meta, 0).unwrap();
        for b in 0..meta.n_blocks {
            let lease = ss.lease_block(b).unwrap();
            for i in (1 + b * 9)..(1 + (b + 1) * 9) {
                assert_eq!(lease.tensor(i), store.tensors[i].as_ref());
            }
            for layer in meta.prunable.iter()
                .filter(|l| l.block == b) {
                assert_eq!(lease.weight(layer).as_slice(),
                           store.weight(layer).as_slice());
            }
            ss.release_block(b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stats_account_bytes_exactly() {
        let (meta, _store, path) = saved_store("bytes");
        let ss = StreamingStore::open(&path, &meta, 0).unwrap();
        let block_bytes: usize = (1..10)
            .map(|i| {
                let n: usize = meta.params[i].1.iter().product();
                n * 4
            })
            .sum();
        assert_eq!(ss.stats().resident_bytes, 0);

        let lease0 = ss.lease_block(0).unwrap();
        assert_eq!(lease0.byte_size(), block_bytes);
        let s = ss.stats();
        assert_eq!(s.resident_bytes, block_bytes);
        assert_eq!(s.loads, 9);
        assert_eq!(s.loaded_bytes, block_bytes);

        // Re-leasing a resident block is free.
        let again = ss.lease_block(0).unwrap();
        assert!(Arc::ptr_eq(lease0.arc(1), again.arc(1)));
        assert_eq!(ss.stats().loads, 9);

        let _lease1 = ss.lease_block(1).unwrap();
        let s = ss.stats();
        assert_eq!(s.resident_bytes, 2 * block_bytes);
        assert_eq!(s.peak_bytes, 2 * block_bytes);

        ss.release_block(0);
        let s = ss.stats();
        assert_eq!(s.resident_bytes, block_bytes);
        assert_eq!(s.peak_bytes, 2 * block_bytes);
        assert_eq!(s.releases, 1);
        // Releasing an already-released block changes nothing.
        ss.release_block(0);
        assert_eq!(ss.stats().releases, 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn over_budget_lease_rejected() {
        let (meta, _store, path) = saved_store("budget");
        let block_bytes: usize = (1..10)
            .map(|i| {
                let n: usize = meta.params[i].1.iter().product();
                n * 4
            })
            .sum();
        // Budget fits one block but not two.
        let ss = StreamingStore::open(&path, &meta,
                                      block_bytes + block_bytes / 2)
            .unwrap();
        let _lease0 = ss.lease_block(0).unwrap();
        match ss.lease_block(1) {
            Err(StoreError::OverBudget { needed, resident, budget }) => {
                assert_eq!(needed, block_bytes);
                assert_eq!(resident, block_bytes);
                assert_eq!(budget, block_bytes + block_bytes / 2);
            }
            other => panic!("expected OverBudget, got {:?}",
                            other.map(|_| "lease")),
        }
        // Releasing block 0 makes room again.
        ss.release_block(0);
        assert!(ss.lease_block(1).is_ok());
        std::fs::remove_file(path).ok();
    }
}
