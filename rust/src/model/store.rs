//! Parameter store: the flat, manifest-ordered list of model tensors the
//! HLO artifacts consume, plus typed access to prunable weight matrices.
//!
//! Tensors are held behind `Arc` so [`ParamStore::masked`] is
//! copy-on-write: only the prunable weights it actually zeroes are
//! duplicated, the norms / embeddings / head stay shared with the
//! source store.  [`ParamStore::weight`] hands out a zero-copy
//! [`MatrixView`] over the stored payload.

use std::sync::Arc;

use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::tensor_data::TensorData;
use crate::util::prng::Rng;
use crate::util::tensor::{Matrix, MatrixView};

#[derive(Clone, Debug)]
pub struct ParamStore {
    pub meta: ModelMeta,
    /// One tensor per manifest `params` entry, same order.  `Arc` so
    /// masking / leasing share unchanged tensors instead of cloning.
    pub tensors: Vec<Arc<TensorData>>,
}

impl ParamStore {
    /// Random init mirroring the python side's scheme (norms = 1, linear
    /// weights gaussian scaled by fan_in^-0.5).  Exact bit-equality with
    /// jax init is *not* required — training happens through the same
    /// HLO either way — but the distributions match.
    pub fn init(meta: &ModelMeta, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let tensors = meta.params.iter().map(|(name, dims)| {
            let n: usize = dims.iter().product();
            if name.ends_with("_norm") {
                Arc::new(TensorData::F32 {
                    dims: dims.clone(),
                    data: vec![1.0; n],
                })
            } else {
                let fan_in = *dims.last().unwrap() as f32;
                let scale = fan_in.powf(-0.5);
                Arc::new(TensorData::F32 {
                    dims: dims.clone(),
                    data: (0..n).map(|_| rng.gaussian_f32() * scale)
                        .collect(),
                })
            }
        }).collect();
        ParamStore { meta: meta.clone(), tensors }
    }

    pub fn zeros_like(meta: &ModelMeta) -> ParamStore {
        let tensors = meta.params.iter().map(|(_, dims)| {
            let n: usize = dims.iter().product();
            Arc::new(TensorData::F32 {
                dims: dims.clone(),
                data: vec![0.0; n],
            })
        }).collect();
        ParamStore { meta: meta.clone(), tensors }
    }

    /// Rebuild a store from owned tensors (manifest order).
    pub fn from_tensors(meta: &ModelMeta, tensors: Vec<TensorData>)
        -> ParamStore {
        ParamStore {
            meta: meta.clone(),
            tensors: tensors.into_iter().map(Arc::new).collect(),
        }
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.element_count()).sum()
    }

    /// Zero-copy weight matrix view of a prunable layer ([d_out, d_in]
    /// paper layout).
    pub fn weight(&self, layer: &PrunableLayer) -> MatrixView<'_> {
        let t = &self.tensors[layer.param_index];
        MatrixView::new(t.as_f32().expect("weights are f32"),
                        layer.d_out, layer.d_in)
    }

    pub fn set_weight(&mut self, layer: &PrunableLayer, w: &Matrix) {
        assert_eq!((w.rows, w.cols), (layer.d_out, layer.d_in));
        let t = Arc::make_mut(&mut self.tensors[layer.param_index]);
        t.as_f32_mut().expect("weights are f32")
            .copy_from_slice(&w.data);
    }

    /// A copy of the store with every prunable weight masked (W ⊙ M).
    /// Copy-on-write: only the prunable tensors are duplicated, every
    /// other tensor is shared with `self`.
    pub fn masked(&self, masks: &MaskSet) -> ParamStore {
        let mut tensors = self.tensors.clone();
        for (layer, mask) in self.meta.prunable.iter().zip(&masks.masks) {
            let src = self.tensors[layer.param_index]
                .as_f32().expect("weights are f32");
            let data: Vec<f32> = src.iter().zip(&mask.data)
                .map(|(&v, &m)| v * m)
                .collect();
            tensors[layer.param_index] = Arc::new(TensorData::F32 {
                dims: self.tensors[layer.param_index].dims().to_vec(),
                data,
            });
        }
        ParamStore { meta: self.meta.clone(), tensors }
    }

    /// Flat clone of all tensors (artifact argument prefix).
    pub fn tensor_args(&self) -> Vec<TensorData> {
        self.tensors.iter().map(|t| (**t).clone()).collect()
    }
}

/// One mask per prunable layer (manifest order).
#[derive(Clone, Debug)]
pub struct MaskSet {
    pub masks: Vec<Matrix>,
}

impl MaskSet {
    pub fn all_ones(meta: &ModelMeta) -> MaskSet {
        MaskSet {
            masks: meta.prunable.iter()
                .map(|l| Matrix::from_fn(l.d_out, l.d_in, |_, _| 1.0))
                .collect(),
        }
    }

    pub fn overall_sparsity(&self) -> f64 {
        let total: usize = self.masks.iter().map(|m| m.data.len()).sum();
        let kept: f64 = self.masks.iter()
            .flat_map(|m| m.data.iter())
            .map(|&v| v as f64)
            .sum();
        1.0 - kept / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn init_shapes_match_meta() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 7);
        assert_eq!(store.tensors.len(), meta.params.len());
        for (t, (_, dims)) in store.tensors.iter().zip(&meta.params) {
            assert_eq!(t.dims(), &dims[..]);
        }
    }

    #[test]
    fn norms_init_to_one() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 7);
        for (i, (name, _)) in meta.params.iter().enumerate() {
            if name.ends_with("_norm") {
                assert!(store.tensors[i].as_f32().unwrap().iter()
                        .all(|&v| v == 1.0), "{name}");
            }
        }
    }

    #[test]
    fn weight_round_trip() {
        let meta = tiny_meta();
        let mut store = ParamStore::init(&meta, 3);
        let layer = meta.prunable[0].clone();
        let mut w = store.weight(&layer).to_matrix();
        w.set(0, 0, 42.0);
        store.set_weight(&layer, &w);
        assert_eq!(store.weight(&layer).at(0, 0), 42.0);
    }

    #[test]
    fn masking_zeroes_weights() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 3);
        let mut masks = MaskSet::all_ones(&meta);
        masks.masks[0].data.fill(0.0);
        let masked = store.masked(&masks);
        let layer = &meta.prunable[0];
        assert!(masked.weight(layer).as_slice().iter()
                .all(|&v| v == 0.0));
        // Other layers untouched.
        let other = &meta.prunable[1];
        assert_eq!(masked.weight(other).as_slice(),
                   store.weight(other).as_slice());
        assert!(masks.overall_sparsity() > 0.0);
    }

    #[test]
    fn masked_is_copy_on_write() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 3);
        let masks = MaskSet::all_ones(&meta);
        let masked = store.masked(&masks);
        let prunable: std::collections::BTreeSet<usize> =
            meta.prunable.iter().map(|l| l.param_index).collect();
        for (i, (a, b)) in
            store.tensors.iter().zip(&masked.tensors).enumerate() {
            if prunable.contains(&i) {
                assert!(!Arc::ptr_eq(a, b), "tensor {i} must be copied");
            } else {
                assert!(Arc::ptr_eq(a, b), "tensor {i} must be shared");
            }
        }
    }

    #[test]
    fn init_deterministic() {
        let meta = tiny_meta();
        let a = ParamStore::init(&meta, 9);
        let b = ParamStore::init(&meta, 9);
        assert_eq!(a.tensors[0].as_f32().unwrap(),
                   b.tensors[0].as_f32().unwrap());
        let c = ParamStore::init(&meta, 10);
        assert_ne!(a.tensors[0].as_f32().unwrap(),
                   c.tensors[0].as_f32().unwrap());
    }
}
