//! Checkpoint format `.ssck`: params (+ optional masks) with CRC32.
//!
//! Layout (little-endian):
//!   magic "SSCK" | u32 version | u32 name_len | name bytes
//!   u32 n_tensors | per tensor: u32 name_len | name | u8 dtype |
//!     u32 ndims | u64 dims[] | payload bytes
//!   u32 n_masks  | per mask: u32 rows | u32 cols | payload f32
//!   u32 crc32 of everything before it
//!
//! Two access paths share the format:
//!   * [`save`]/[`load`] materialise a whole [`ParamStore`] (resident
//!     path).
//!   * [`CheckpointReader`] validates the file once (chunked CRC +
//!     header scan, O(chunk) memory) and then serves individual
//!     tensors by byte offset — the backing for
//!     `model::weight_store::StreamingStore`.  [`save_streaming`]
//!     writes the identical byte layout from any
//!     [`WeightStore`](crate::model::weight_store::WeightStore),
//!     leasing one block at a time.

use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::model::store::{MaskSet, ParamStore};
use crate::model::weight_store::WeightStore;
use crate::runtime::manifest::ModelMeta;
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::Matrix;

const MAGIC: &[u8; 4] = b"SSCK";
const VERSION: u32 = 1;
/// Chunk size for the streaming CRC pass (bounds reader memory).
const CRC_CHUNK: usize = 1 << 20;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Format(s) => write!(f, "format: {s}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Format(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

// --- CRC32 (IEEE, table-driven) -------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    for (i, entry) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 == 1 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *entry = c;
    }
    table
}

/// Incremental CRC32 state update: feed chunks in order, starting from
/// [`CRC_INIT`]; finalise with `^ CRC_INIT`.
fn crc32_update(mut state: u32, data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> =
        std::sync::OnceLock::new();
    let table = TABLE.get_or_init(crc32_table);
    for &b in data {
        state = table[((state ^ b as u32) & 0xFF) as usize]
            ^ (state >> 8);
    }
    state
}

const CRC_INIT: u32 = 0xFFFF_FFFF;

pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(CRC_INIT, data) ^ CRC_INIT
}

// --- serialisation ----------------------------------------------------------

/// Write sink that folds every byte into a running CRC32 so the
/// trailing checksum never needs the whole file in memory.
struct CrcWriter<W: Write> {
    sink: W,
    crc: u32,
}

impl<W: Write> CrcWriter<W> {
    fn new(sink: W) -> CrcWriter<W> {
        CrcWriter { sink, crc: CRC_INIT }
    }

    fn bytes(&mut self, data: &[u8]) -> Result<(), CheckpointError> {
        self.crc = crc32_update(self.crc, data);
        self.sink.write_all(data)?;
        Ok(())
    }

    fn u8(&mut self, v: u8) -> Result<(), CheckpointError> {
        self.bytes(&[v])
    }

    fn u32(&mut self, v: u32) -> Result<(), CheckpointError> {
        self.bytes(&v.to_le_bytes())
    }

    fn string(&mut self, s: &str) -> Result<(), CheckpointError> {
        self.u32(s.len() as u32)?;
        self.bytes(s.as_bytes())
    }

    /// Append the checksum (not itself checksummed) and flush.
    fn finish(mut self) -> Result<(), CheckpointError> {
        let crc = self.crc ^ CRC_INIT;
        self.sink.write_all(&crc.to_le_bytes())?;
        self.sink.flush()?;
        Ok(())
    }
}

fn f32_bytes(data: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    }
}

fn tensor_bytes(t: &TensorData) -> (&[usize], u8, &[u8]) {
    match t {
        TensorData::F32 { dims, data } => (dims, 0, f32_bytes(data)),
        TensorData::I32 { dims, data } => (dims, 1, unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        }),
    }
}

fn write_tensor<W: Write>(w: &mut CrcWriter<W>, name: &str,
                          t: &TensorData) -> Result<(), CheckpointError> {
    w.string(name)?;
    let (dims, dtype, payload) = tensor_bytes(t);
    w.u8(dtype)?;
    w.u32(dims.len() as u32)?;
    for &d in dims {
        w.bytes(&(d as u64).to_le_bytes())?;
    }
    w.bytes(payload)
}

fn write_masks<W: Write>(w: &mut CrcWriter<W>, masks: Option<&MaskSet>)
    -> Result<(), CheckpointError> {
    match masks {
        Some(ms) => {
            w.u32(ms.masks.len() as u32)?;
            for m in &ms.masks {
                w.u32(m.rows as u32)?;
                w.u32(m.cols as u32)?;
                w.bytes(f32_bytes(&m.data))?;
            }
        }
        None => w.u32(0)?,
    }
    Ok(())
}

fn open_writer(path: &Path)
    -> Result<CrcWriter<BufWriter<std::fs::File>>, CheckpointError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    Ok(CrcWriter::new(BufWriter::new(std::fs::File::create(path)?)))
}

pub fn save(path: impl AsRef<Path>, store: &ParamStore,
            masks: Option<&MaskSet>) -> Result<(), CheckpointError> {
    let mut w = open_writer(path.as_ref())?;
    w.bytes(MAGIC)?;
    w.u32(VERSION)?;
    w.string(&store.meta.name)?;
    w.u32(store.tensors.len() as u32)?;
    for ((name, _), t) in store.meta.params.iter().zip(&store.tensors) {
        write_tensor(&mut w, name, t)?;
    }
    write_masks(&mut w, masks)?;
    w.finish()
}

/// [`save`] through the block-lease interface: one block of tensors is
/// resident at a time, so an out-of-core store round-trips to disk
/// without ever materialising the full parameter set.  Byte-identical
/// to [`save`] of the equivalent resident store.
pub fn save_streaming(path: impl AsRef<Path>, store: &dyn WeightStore,
                      masks: Option<&MaskSet>)
    -> Result<(), CheckpointError> {
    let meta = store.meta().clone();
    let mut w = open_writer(path.as_ref())?;
    w.bytes(MAGIC)?;
    w.u32(VERSION)?;
    w.string(&meta.name)?;
    w.u32(meta.params.len() as u32)?;
    let globals = store.lease_globals()
        .map_err(|e| CheckpointError::Format(e.to_string()))?;
    let i_final_norm = 1 + meta.n_blocks * 9;
    write_tensor(&mut w, &meta.params[0].0, globals.tensor(0))?;
    for b in 0..meta.n_blocks {
        let lease = store.lease_block(b)
            .map_err(|e| CheckpointError::Format(e.to_string()))?;
        for i in (1 + b * 9)..(1 + (b + 1) * 9) {
            write_tensor(&mut w, &meta.params[i].0, lease.tensor(i))?;
        }
        drop(lease);
        store.release_block(b);
    }
    for i in [i_final_norm, i_final_norm + 1] {
        write_tensor(&mut w, &meta.params[i].0, globals.tensor(i))?;
    }
    drop(globals);
    store.release_globals();
    write_masks(&mut w, masks)?;
    w.finish()
}

// --- lazy reader ------------------------------------------------------------

/// Buffered cursor over the checkpoint file that tracks its absolute
/// position, for the header scan.
struct FileCursor {
    f: BufReader<std::fs::File>,
    pos: u64,
}

impl FileCursor {
    fn take(&mut self, n: usize) -> Result<Vec<u8>, CheckpointError> {
        let mut buf = vec![0u8; n];
        self.f.read_exact(&mut buf).map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof =>
                CheckpointError::Format("truncated file".into()),
            _ => CheckpointError::Io(e),
        })?;
        self.pos += n as u64;
        Ok(buf)
    }

    fn skip(&mut self, n: u64) -> Result<(), CheckpointError> {
        self.f.seek_relative(n as i64)?;
        self.pos += n;
        Ok(())
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String, CheckpointError> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?)
            .map_err(|e| CheckpointError::Format(e.to_string()))
    }
}

fn f32_from_le(payload: &[u8]) -> Vec<f32> {
    payload.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Validated handle on a `.ssck` file that loads tensors on demand.
///
/// `open` makes two bounded-memory passes: a chunked CRC sweep over the
/// whole file, then a header scan that records each tensor's payload
/// offset (skipping the payload bytes) and eagerly decodes the small
/// trailing mask section.  [`load_tensor`](Self::load_tensor) then
/// reads exactly one tensor's bytes per call, so peak reader memory is
/// one tensor, not one checkpoint.
pub struct CheckpointReader {
    path: PathBuf,
    pub meta: ModelMeta,
    /// (payload byte offset, dtype tag) per manifest tensor.
    offsets: Vec<(u64, u8)>,
    masks: Option<MaskSet>,
}

impl CheckpointReader {
    pub fn open(path: impl AsRef<Path>, meta: &ModelMeta)
        -> Result<CheckpointReader, CheckpointError> {
        let path = path.as_ref().to_path_buf();
        let mut f = std::fs::File::open(&path)?;
        let len = f.metadata()?.len();
        if len < 8 {
            return Err(CheckpointError::Format("truncated file".into()));
        }

        // Pass 1: chunked CRC over everything before the trailing u32.
        let body = len - 4;
        let mut state = CRC_INIT;
        let mut remaining = body;
        let mut chunk = vec![0u8; CRC_CHUNK];
        while remaining > 0 {
            let here = remaining.min(CRC_CHUNK as u64) as usize;
            f.read_exact(&mut chunk[..here])?;
            state = crc32_update(state, &chunk[..here]);
            remaining -= here as u64;
        }
        let mut crc_bytes = [0u8; 4];
        f.read_exact(&mut crc_bytes)?;
        let stored_crc = u32::from_le_bytes(crc_bytes);
        let actual = state ^ CRC_INIT;
        if stored_crc != actual {
            return Err(CheckpointError::Format(format!(
                "crc mismatch: stored {stored_crc:#x}, \
                 computed {actual:#x}")));
        }

        // Pass 2: header scan.
        f.seek(SeekFrom::Start(0))?;
        let mut cur = FileCursor { f: BufReader::new(f), pos: 0 };
        if &cur.take(4)?[..] != MAGIC {
            return Err(CheckpointError::Format("bad magic".into()));
        }
        let version = cur.u32()?;
        if version != VERSION {
            return Err(CheckpointError::Format(format!(
                "unsupported version {version}")));
        }
        let cfg_name = cur.string()?;
        if cfg_name != meta.name {
            return Err(CheckpointError::Format(format!(
                "checkpoint is for config {cfg_name:?}, expected {:?}",
                meta.name)));
        }
        let n_tensors = cur.u32()? as usize;
        if n_tensors != meta.params.len() {
            return Err(CheckpointError::Format(format!(
                "checkpoint has {n_tensors} tensors, manifest \
                 expects {}", meta.params.len())));
        }
        let mut offsets = Vec::with_capacity(n_tensors);
        for (name, want_dims) in &meta.params {
            let got_name = cur.string()?;
            if &got_name != name {
                return Err(CheckpointError::Format(format!(
                    "tensor order mismatch: got {got_name:?}, \
                     want {name:?}")));
            }
            let dtype = cur.take(1)?[0];
            if dtype > 1 {
                return Err(CheckpointError::Format(format!(
                    "unknown dtype tag {dtype}")));
            }
            let ndims = cur.u32()? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(cur.u64()? as usize);
            }
            if &dims != want_dims {
                return Err(CheckpointError::Format(format!(
                    "{name}: dims {dims:?} != manifest {want_dims:?}")));
            }
            let n: usize = dims.iter().product();
            offsets.push((cur.pos, dtype));
            if cur.pos + (n * 4) as u64 > body {
                return Err(CheckpointError::Format(
                    "truncated file".into()));
            }
            cur.skip((n * 4) as u64)?;
        }
        let n_masks = cur.u32()? as usize;
        let masks = if n_masks > 0 {
            if n_masks != meta.prunable.len() {
                return Err(CheckpointError::Format(format!(
                    "checkpoint has {n_masks} masks, expected {}",
                    meta.prunable.len())));
            }
            let mut ms = Vec::with_capacity(n_masks);
            for layer in &meta.prunable {
                let rows = cur.u32()? as usize;
                let cols = cur.u32()? as usize;
                if (rows, cols) != (layer.d_out, layer.d_in) {
                    return Err(CheckpointError::Format(format!(
                        "mask shape {rows}x{cols} != layer {}x{}",
                        layer.d_out, layer.d_in)));
                }
                let payload = cur.take(rows * cols * 4)?;
                ms.push(Matrix::from_vec(rows, cols,
                                         f32_from_le(&payload)));
            }
            Some(MaskSet { masks: ms })
        } else {
            None
        };
        Ok(CheckpointReader {
            path,
            meta: meta.clone(),
            offsets,
            masks,
        })
    }

    /// Masks stored alongside the params, decoded eagerly at `open`.
    pub fn masks(&self) -> Option<&MaskSet> {
        self.masks.as_ref()
    }

    pub fn take_masks(&mut self) -> Option<MaskSet> {
        self.masks.take()
    }

    /// Read one tensor's payload from disk.  Stateless (opens its own
    /// handle), so concurrent loads from different threads are safe.
    pub fn load_tensor(&self, param_index: usize)
        -> Result<TensorData, CheckpointError> {
        let (offset, dtype) = self.offsets[param_index];
        let dims = self.meta.params[param_index].1.clone();
        let n: usize = dims.iter().product();
        let mut f = std::fs::File::open(&self.path)?;
        f.seek(SeekFrom::Start(offset))?;
        let mut payload = vec![0u8; n * 4];
        f.read_exact(&mut payload)?;
        Ok(match dtype {
            0 => TensorData::F32 { dims, data: f32_from_le(&payload) },
            _ => TensorData::I32 {
                dims,
                data: payload.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
        })
    }
}

pub fn load(path: impl AsRef<Path>, meta: &ModelMeta)
    -> Result<(ParamStore, Option<MaskSet>), CheckpointError> {
    let mut reader = CheckpointReader::open(path, meta)?;
    let tensors = (0..meta.params.len())
        .map(|i| reader.load_tensor(i))
        .collect::<Result<Vec<_>, _>>()?;
    let masks = reader.take_masks();
    Ok((ParamStore::from_tensors(meta, tensors), masks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;
    use crate::pruning::mask::{mask_from_scores, Pattern};

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b"hello"), 0x3610A686);
        // Incremental chunked update matches the one-shot digest.
        let data = b"incremental crc must chunk cleanly";
        let mut state = CRC_INIT;
        for chunk in data.chunks(7) {
            state = crc32_update(state, chunk);
        }
        assert_eq!(state ^ CRC_INIT, crc32(data));
    }

    #[test]
    fn round_trip_params_only() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_params.ssck");
        save(&path, &store, None).unwrap();
        let (loaded, masks) = load(&path, &meta).unwrap();
        assert!(masks.is_none());
        for (a, b) in store.tensors.iter().zip(&loaded.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn round_trip_with_masks() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let mut masks = MaskSet::all_ones(&meta);
        for (i, layer) in meta.prunable.iter().enumerate() {
            let w = store.weight(layer);
            let scores = crate::pruning::saliency::magnitude(w);
            masks.masks[i] = mask_from_scores(
                &scores, Pattern::PerRow { keep: layer.d_in / 2 });
        }
        let path = std::env::temp_dir().join("ssck_test_masks.ssck");
        save(&path, &store, Some(&masks)).unwrap();
        let (_, loaded) = load(&path, &meta).unwrap();
        let loaded = loaded.unwrap();
        for (a, b) in masks.masks.iter().zip(&loaded.masks) {
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corruption_detected() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_corrupt.ssck");
        save(&path, &store, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load(&path, &meta),
                         Err(CheckpointError::Format(_))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_cfg.ssck");
        save(&path, &store, None).unwrap();
        let mut other = tiny_meta();
        other.name = "other".into();
        assert!(load(&path, &other).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn reader_serves_single_tensors() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_reader.ssck");
        save(&path, &store, None).unwrap();
        let reader = CheckpointReader::open(&path, &meta).unwrap();
        assert!(reader.masks().is_none());
        // Out-of-order single-tensor loads round-trip exactly.
        for i in (0..meta.params.len()).rev() {
            let t = reader.load_tensor(i).unwrap();
            assert_eq!(&t, store.tensors[i].as_ref(),
                       "tensor {i} mismatch");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_streaming_is_byte_identical() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let mut masks = MaskSet::all_ones(&meta);
        masks.masks[0].data.fill(0.0);
        let p_res = std::env::temp_dir().join("ssck_test_res.ssck");
        let p_str = std::env::temp_dir().join("ssck_test_str.ssck");
        save(&p_res, &store, Some(&masks)).unwrap();
        save_streaming(&p_str, &store, Some(&masks)).unwrap();
        assert_eq!(std::fs::read(&p_res).unwrap(),
                   std::fs::read(&p_str).unwrap());
        std::fs::remove_file(p_res).ok();
        std::fs::remove_file(p_str).ok();
    }
}
