//! Checkpoint format `.ssck`: params (+ optional masks) with CRC32.
//!
//! Layout (little-endian):
//!   magic "SSCK" | u32 version | u32 name_len | name bytes
//!   u32 n_tensors | per tensor: u32 name_len | name | u8 dtype |
//!     u32 ndims | u64 dims[] | payload bytes
//!   u32 n_masks  | per mask: u32 rows | u32 cols | payload f32
//!   u32 crc32 of everything before it

use std::io::{Read, Write};
use std::path::Path;

use crate::model::store::{MaskSet, ParamStore};
use crate::runtime::manifest::ModelMeta;
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::Matrix;

const MAGIC: &[u8; 4] = b"SSCK";
const VERSION: u32 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Format(s) => write!(f, "format: {s}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Format(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

// --- CRC32 (IEEE, table-driven) -------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    for (i, entry) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 == 1 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *entry = c;
    }
    table
}

pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> =
        std::sync::OnceLock::new();
    let table = TABLE.get_or_init(crc32_table);
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// --- serialisation ----------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.pos + n > self.buf.len() {
            return Err(CheckpointError::Format("truncated file".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String, CheckpointError> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|e| CheckpointError::Format(e.to_string()))
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_string(buf: &mut Vec<u8>, s: &str) {
    push_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn tensor_bytes(t: &TensorData) -> (&[usize], u8, &[u8]) {
    match t {
        TensorData::F32 { dims, data } => (dims, 0, unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        }),
        TensorData::I32 { dims, data } => (dims, 1, unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        }),
    }
}

pub fn save(path: impl AsRef<Path>, store: &ParamStore,
            masks: Option<&MaskSet>) -> Result<(), CheckpointError> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    push_string(&mut buf, &store.meta.name);
    push_u32(&mut buf, store.tensors.len() as u32);
    for ((name, _), t) in store.meta.params.iter().zip(&store.tensors) {
        push_string(&mut buf, name);
        let (dims, dtype, payload) = tensor_bytes(t);
        buf.push(dtype);
        push_u32(&mut buf, dims.len() as u32);
        for &d in dims {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(payload);
    }
    match masks {
        Some(ms) => {
            push_u32(&mut buf, ms.masks.len() as u32);
            for m in &ms.masks {
                push_u32(&mut buf, m.rows as u32);
                push_u32(&mut buf, m.cols as u32);
                buf.extend_from_slice(unsafe {
                    std::slice::from_raw_parts(
                        m.data.as_ptr() as *const u8, m.data.len() * 4)
                });
            }
        }
        None => push_u32(&mut buf, 0),
    }
    let crc = crc32(&buf);
    push_u32(&mut buf, crc);
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>, meta: &ModelMeta)
    -> Result<(ParamStore, Option<MaskSet>), CheckpointError> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 8 || &buf[..4] != MAGIC {
        return Err(CheckpointError::Format("bad magic".into()));
    }
    let stored_crc = u32::from_le_bytes(
        buf[buf.len() - 4..].try_into().unwrap());
    let actual = crc32(&buf[..buf.len() - 4]);
    if stored_crc != actual {
        return Err(CheckpointError::Format(format!(
            "crc mismatch: stored {stored_crc:#x}, computed {actual:#x}")));
    }
    let mut cur = Cursor { buf: &buf[..buf.len() - 4], pos: 4 };
    let version = cur.u32()?;
    if version != VERSION {
        return Err(CheckpointError::Format(format!(
            "unsupported version {version}")));
    }
    let cfg_name = cur.string()?;
    if cfg_name != meta.name {
        return Err(CheckpointError::Format(format!(
            "checkpoint is for config {cfg_name:?}, expected {:?}",
            meta.name)));
    }
    let n_tensors = cur.u32()? as usize;
    if n_tensors != meta.params.len() {
        return Err(CheckpointError::Format(format!(
            "checkpoint has {n_tensors} tensors, manifest expects {}",
            meta.params.len())));
    }
    let mut tensors = Vec::with_capacity(n_tensors);
    for (name, want_dims) in &meta.params {
        let got_name = cur.string()?;
        if &got_name != name {
            return Err(CheckpointError::Format(format!(
                "tensor order mismatch: got {got_name:?}, want {name:?}")));
        }
        let dtype = cur.take(1)?[0];
        let ndims = cur.u32()? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(cur.u64()? as usize);
        }
        if &dims != want_dims {
            return Err(CheckpointError::Format(format!(
                "{name}: dims {dims:?} != manifest {want_dims:?}")));
        }
        let n: usize = dims.iter().product();
        let payload = cur.take(n * 4)?;
        let tensor = match dtype {
            0 => TensorData::F32 {
                dims,
                data: payload.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            1 => TensorData::I32 {
                dims,
                data: payload.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            other => return Err(CheckpointError::Format(format!(
                "unknown dtype tag {other}"))),
        };
        tensors.push(tensor);
    }
    let n_masks = cur.u32()? as usize;
    let masks = if n_masks > 0 {
        if n_masks != meta.prunable.len() {
            return Err(CheckpointError::Format(format!(
                "checkpoint has {n_masks} masks, expected {}",
                meta.prunable.len())));
        }
        let mut ms = Vec::with_capacity(n_masks);
        for layer in &meta.prunable {
            let rows = cur.u32()? as usize;
            let cols = cur.u32()? as usize;
            if (rows, cols) != (layer.d_out, layer.d_in) {
                return Err(CheckpointError::Format(format!(
                    "mask shape {rows}x{cols} != layer {}x{}",
                    layer.d_out, layer.d_in)));
            }
            let payload = cur.take(rows * cols * 4)?;
            ms.push(Matrix::from_vec(rows, cols,
                payload.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect()));
        }
        Some(MaskSet { masks: ms })
    } else {
        None
    };
    Ok((ParamStore { meta: meta.clone(), tensors }, masks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;
    use crate::pruning::mask::{mask_from_scores, Pattern};

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b"hello"), 0x3610A686);
    }

    #[test]
    fn round_trip_params_only() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_params.ssck");
        save(&path, &store, None).unwrap();
        let (loaded, masks) = load(&path, &meta).unwrap();
        assert!(masks.is_none());
        for (a, b) in store.tensors.iter().zip(&loaded.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn round_trip_with_masks() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let mut masks = MaskSet::all_ones(&meta);
        for (i, layer) in meta.prunable.iter().enumerate() {
            let w = store.weight(layer);
            let scores = crate::pruning::saliency::magnitude(&w);
            masks.masks[i] = mask_from_scores(
                &scores, Pattern::PerRow { keep: layer.d_in / 2 });
        }
        let path = std::env::temp_dir().join("ssck_test_masks.ssck");
        save(&path, &store, Some(&masks)).unwrap();
        let (_, loaded) = load(&path, &meta).unwrap();
        let loaded = loaded.unwrap();
        for (a, b) in masks.masks.iter().zip(&loaded.masks) {
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corruption_detected() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_corrupt.ssck");
        save(&path, &store, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load(&path, &meta),
                         Err(CheckpointError::Format(_))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let meta = tiny_meta();
        let store = ParamStore::init(&meta, 5);
        let path = std::env::temp_dir().join("ssck_test_cfg.ssck");
        save(&path, &store, None).unwrap();
        let mut other = tiny_meta();
        other.name = "other".into();
        assert!(load(&path, &other).is_err());
        std::fs::remove_file(path).ok();
    }
}
