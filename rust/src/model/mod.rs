//! Model-side substrates: the flat parameter store the artifacts
//! consume, checkpoint io, and shared test fixtures.

pub mod checkpoint;
pub mod store;
pub mod testutil;

pub use store::{MaskSet, ParamStore};
