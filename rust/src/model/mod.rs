//! Model-side substrates: the flat parameter store the artifacts
//! consume, block-granular weight leasing, checkpoint io, and shared
//! test fixtures.

pub mod checkpoint;
pub mod store;
pub mod testutil;
pub mod weight_store;

pub use store::{MaskSet, ParamStore};
pub use weight_store::{BlockLease, ResidentStore, StoreError,
                       StoreStats, StreamingStore, WeightStore};
