//! Shared test fixtures: a hand-built `ModelMeta` mirroring the python
//! "tiny" config, available without artifacts on disk.

use crate::runtime::manifest::{ModelMeta, PrunableLayer};

/// Mirror of `configs.MODEL_CONFIGS["tiny"]` (python side).
pub fn tiny_meta() -> ModelMeta {
    meta_for(256, 64, 2, 128, 2, 32, 4)
}

pub fn meta_for(vocab: usize, d_model: usize, n_heads: usize, d_ff: usize,
                n_blocks: usize, seq_len: usize, batch: usize)
    -> ModelMeta {
    let mut params: Vec<(String, Vec<usize>)> =
        vec![("tok_emb".into(), vec![vocab, d_model])];
    let mut prunable = Vec::new();
    let streams = [
        ("attn.q_proj", "qkv", d_model, d_model),
        ("attn.k_proj", "qkv", d_model, d_model),
        ("attn.v_proj", "qkv", d_model, d_model),
        ("attn.o_proj", "o", d_model, d_model),
        ("mlp.gate_proj", "gu", d_ff, d_model),
        ("mlp.up_proj", "gu", d_ff, d_model),
        ("mlp.down_proj", "down", d_model, d_ff),
    ];
    for b in 0..n_blocks {
        params.push((format!("blocks.{b}.attn_norm"), vec![d_model]));
        for &(lt, stream, d_out, d_in) in &streams[..4] {
            let idx = params.len();
            params.push((format!("blocks.{b}.{lt}"), vec![d_out, d_in]));
            prunable.push(PrunableLayer {
                param_index: idx,
                name: format!("blocks.{b}.{lt}"),
                layer_type: lt.to_string(),
                block: b,
                d_out,
                d_in,
                stream: stream.to_string(),
            });
        }
        params.push((format!("blocks.{b}.mlp_norm"), vec![d_model]));
        for &(lt, stream, d_out, d_in) in &streams[4..] {
            let idx = params.len();
            params.push((format!("blocks.{b}.{lt}"), vec![d_out, d_in]));
            prunable.push(PrunableLayer {
                param_index: idx,
                name: format!("blocks.{b}.{lt}"),
                layer_type: lt.to_string(),
                block: b,
                d_out,
                d_in,
                stream: stream.to_string(),
            });
        }
    }
    params.push(("final_norm".into(), vec![d_model]));
    params.push(("lm_head".into(), vec![vocab, d_model]));
    ModelMeta {
        name: "tiny".into(),
        vocab,
        d_model,
        n_heads,
        d_ff,
        n_blocks,
        seq_len,
        batch,
        rope_theta: 10000.0,
        init_seed: 7,
        params,
        prunable,
    }
}

/// In-memory manifest exposing the full artifact surface (model
/// kinds + swap/layer-loss) for [`tiny_meta`], interp-executable —
/// the whole train → calibrate → prune → refine → eval cycle runs
/// without `make artifacts` (see `runtime::testutil::model_manifest`).
pub fn tiny_manifest() -> crate::runtime::manifest::Manifest {
    crate::runtime::testutil::model_manifest(&tiny_meta())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_meta_consistent() {
        let m = tiny_meta();
        // 1 emb + 2 blocks * 9 + final_norm + lm_head
        assert_eq!(m.params.len(), 1 + 2 * 9 + 2);
        assert_eq!(m.prunable.len(), 14);
        for p in &m.prunable {
            assert_eq!(m.params[p.param_index].1, vec![p.d_out, p.d_in]);
        }
    }
}
