//! Byte-level BPE tokenizer, trained in-repo (standing in for the HF
//! tokenizers the paper's models use; see DESIGN.md section 2).
//!
//! Training: start from the 256 byte tokens, repeatedly merge the most
//! frequent adjacent pair until the target vocab size.  Encoding applies
//! merges greedily in rank order (the standard BPE scheme).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// Target vocabulary size (>= 256).
    pub vocab_size: usize,
    /// Merge rules in application order: (left, right) -> new token id.
    pub merges: Vec<(u32, u32)>,
    merge_rank: HashMap<(u32, u32), usize>,
    /// Byte sequences per token id (for decoding).
    pieces: Vec<Vec<u8>>,
}

impl Tokenizer {
    /// Pure byte-level tokenizer (no merges).
    pub fn byte_level() -> Tokenizer {
        Tokenizer {
            vocab_size: 256,
            merges: Vec::new(),
            merge_rank: HashMap::new(),
            pieces: (0..=255u8).map(|b| vec![b]).collect(),
        }
    }

    /// Train BPE merges on `text` up to `vocab_size` tokens.
    pub fn train(text: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size >= 256, "vocab must cover raw bytes");
        let mut tok = Tokenizer::byte_level();
        tok.vocab_size = vocab_size;
        // Work on a word-segmented corpus so merges never cross spaces
        // (keeps the learned pieces linguistic-ish and training fast).
        let mut words: HashMap<Vec<u32>, usize> = HashMap::new();
        for w in text.split_whitespace() {
            // Prefix each word with a space marker byte so word-initial
            // pieces are distinct (GPT-2 style).
            let mut ids: Vec<u32> = vec![b' ' as u32];
            ids.extend(w.bytes().map(|b| b as u32));
            *words.entry(ids).or_insert(0) += 1;
        }
        while tok.pieces.len() < vocab_size {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (ids, &freq) in &words {
                for win in ids.windows(2) {
                    *counts.entry((win[0], win[1])).or_insert(0) += freq;
                }
            }
            // Deterministic argmax: highest count, then lowest pair ids.
            let Some((&pair, &count)) = counts.iter().max_by(
                |(p1, c1), (p2, c2)| c1.cmp(c2)
                    .then(p2.cmp(p1))) else { break };
            if count < 2 {
                break;
            }
            let new_id = tok.pieces.len() as u32;
            let mut piece = tok.pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&tok.pieces[pair.1 as usize]);
            tok.pieces.push(piece);
            tok.merge_rank.insert(pair, tok.merges.len());
            tok.merges.push(pair);
            // Apply the merge to every word.
            words = words.into_iter().map(|(ids, freq)| {
                (merge_once(&ids, pair, new_id), freq)
            }).collect();
        }
        tok
    }

    pub fn actual_vocab(&self) -> usize {
        self.pieces.len()
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for w in text.split_inclusive(' ') {
            // Keep the trailing space attached to the *next* word as a
            // marker, matching training segmentation.
            let mut ids: Vec<u32> = w.bytes().map(|b| b as u32).collect();
            // Apply merges in rank order until none applies.
            loop {
                let mut best: Option<(usize, usize)> = None; // (rank, pos)
                for (pos, win) in ids.windows(2).enumerate() {
                    if let Some(&rank) =
                        self.merge_rank.get(&(win[0], win[1])) {
                        if best.map_or(true, |(br, _)| rank < br) {
                            best = Some((rank, pos));
                        }
                    }
                }
                let Some((rank, pos)) = best else { break };
                let (l, r) = self.merges[rank];
                let new_id = self.id_of_merge(rank);
                let _ = (l, r);
                ids.splice(pos..pos + 2, [new_id]);
            }
            out.extend(ids);
        }
        out
    }

    fn id_of_merge(&self, rank: usize) -> u32 {
        256 + rank as u32
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

fn merge_once(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the quick brown fox jumps over the lazy dog \
                          the quick brown fox the quick the";

    #[test]
    fn byte_level_round_trip() {
        let tok = Tokenizer::byte_level();
        let ids = tok.encode("hello world");
        assert_eq!(tok.decode(&ids), "hello world");
        assert!(ids.iter().all(|&i| i < 256));
    }

    #[test]
    fn trained_round_trip() {
        let tok = Tokenizer::train(SAMPLE, 300);
        for text in [SAMPLE, "the quick dog", "unseen words zebra!"] {
            let ids = tok.encode(text);
            assert_eq!(tok.decode(&ids), text, "{text}");
        }
    }

    #[test]
    fn merges_compress() {
        let tok = Tokenizer::train(SAMPLE, 320);
        let byte_len = SAMPLE.len();
        let bpe_len = tok.encode(SAMPLE).len();
        assert!(bpe_len < byte_len, "{bpe_len} !< {byte_len}");
        assert!(tok.actual_vocab() > 256);
    }

    #[test]
    fn ids_within_vocab() {
        let tok = Tokenizer::train(SAMPLE, 280);
        let ids = tok.encode("the quick brown fox and some new text");
        assert!(ids.iter().all(|&i| (i as usize) < tok.actual_vocab()));
    }

    #[test]
    fn training_deterministic() {
        let a = Tokenizer::train(SAMPLE, 300);
        let b = Tokenizer::train(SAMPLE, 300);
        assert_eq!(a.merges, b.merges);
        assert_eq!(a.encode(SAMPLE), b.encode(SAMPLE));
    }

    #[test]
    fn unicode_survives() {
        let tok = Tokenizer::train(SAMPLE, 270);
        let text = "naïve café ↦ λ";
        assert_eq!(tok.decode(&tok.encode(text)), text);
    }
}
