//! `sparseswaps` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   train    train a zoo model through the AOT train-step artifact
//!   prune    run the pruning pipeline (warmstart + refinement)
//!   sweep    ppl-vs-sparsity curves via warm-started mask continuation
//!   eval     perplexity + zero-shot accuracy of a checkpoint
//!   report   regenerate a paper table/figure (table1..table5, fig1, fig2)
//!   inspect  list manifest artifacts and model configs

use std::process::ExitCode;

use sparseswaps::coordinator::{
    sweep, train, MaskSpec, PatternKind, PruneSession, Refiner,
    RunOptions, SweepConfig, TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, zeroshot};
use sparseswaps::model::{checkpoint, ParamStore, StreamingStore,
                         WeightStore};
use sparseswaps::pruning::Criterion;
use sparseswaps::report;
use sparseswaps::runtime::{Runtime, RuntimeOptions, RuntimePool};
use sparseswaps::util::benchlib::Table;
use sparseswaps::util::cli::{ArgSpec, JournalFlags, PoolFlags};
use sparseswaps::util::logging;

fn main() -> ExitCode {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprint!("{}", top_usage());
        return ExitCode::FAILURE;
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "prune" => cmd_prune(rest),
        "sweep" => cmd_sweep(rest),
        "eval" => cmd_eval(rest),
        "report" => cmd_report(rest),
        "inspect" => cmd_inspect(rest),
        "analyze" => cmd_analyze(rest),
        "--help" | "-h" | "help" => {
            print!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}\n{}",
                             top_usage()).into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn top_usage() -> String {
    "sparseswaps — LLM pruning mask refinement (Zimmer et al., 2025)\n\n\
     USAGE:\n  sparseswaps \
     <train|prune|sweep|eval|report|analyze|inspect> [FLAGS]\n\n\
     Run `sparseswaps <cmd> --help` for per-command flags.\n".into()
}

fn runtime(args: &sparseswaps::util::cli::Args) -> Result<Runtime, String> {
    Runtime::start(args.get("artifacts")).map_err(|e| e.to_string())
}

/// Worker count + runtime options from the shared pool flag block
/// (0 devices = all cores; budget in MiB, 0 = unlimited).
fn pool_opts(pf: &PoolFlags) -> (usize, RuntimeOptions) {
    let devices = match pf.devices {
        0 => sparseswaps::util::threadpool::default_threads(),
        n => n,
    };
    let opts = RuntimeOptions {
        device_mem_budget: pf.device_mem_budget_mib
            .saturating_mul(1 << 20),
        ..RuntimeOptions::default()
    };
    (devices, opts)
}

/// Start a runtime pool honoring the shared journal/fault flag block
/// (fault plan, quarantine threshold).
fn start_pool(artifacts: &str, devices: usize, opts: RuntimeOptions,
              jf: &JournalFlags)
    -> Result<RuntimePool, Box<dyn std::error::Error>> {
    let fault_plan = match jf.fault_plan.as_str() {
        "" => sparseswaps::runtime::FaultPlan::from_env()?,
        spec => Some(sparseswaps::runtime::FaultPlan::parse(spec)?),
    };
    let rt = match fault_plan {
        Some(plan) => RuntimePool::start_with_faults(artifacts, devices,
                                                     opts, plan),
        None => RuntimePool::start(artifacts, devices, opts),
    }
    .map_err(|e| e.to_string())?;
    rt.set_quarantine_after(jf.quarantine_after);
    Ok(rt)
}

fn print_pool_stats(rt: &RuntimePool) {
    let ps = rt.stats_total();
    if ps.executions > 0 {
        println!("  runtime pool: {} device(s), {} artifact execs, \
                  buffer cache {}/{} hits ({:.0}%), {} evictions, \
                  {:.1} MiB summed per-device peaks, {} compiles \
                  ({} adopted from the shared cache)",
                 rt.devices(), ps.executions, ps.cache_hits,
                 ps.cache_hits + ps.cache_misses,
                 100.0 * ps.cache_hit_rate(), ps.cache_evictions,
                 ps.cache_peak_bytes as f64 / (1u64 << 20) as f64,
                 ps.compiles, ps.compiles_shared);
        println!("  key-only probes: {}/{} resident ({:.0}%), \
                  {:.1} MiB uploaded",
                 ps.probe_hits, ps.probe_hits + ps.probe_misses,
                 100.0 * ps.probe_hit_rate(),
                 ps.upload_bytes as f64 / (1u64 << 20) as f64);
    }
    if ps.shard_retries > 0 || ps.workers_quarantined > 0 {
        println!("  fault recovery: {} shard retries, {} worker(s) \
                  quarantined",
                 ps.shard_retries, ps.workers_quarantined);
    }
}

fn cmd_train(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps train",
                            "train a model via the AOT train-step")
        .flag("config", "gpt-a", "model config name from the manifest")
        .flag("steps", "300", "training steps")
        .flag("lr", "0.002", "Adam learning rate")
        .flag("batches", "24", "distinct training batches to cycle")
        .flag("seed", "42", "dataset seed")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "runs/model.ssck", "output checkpoint path");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let cfg = TrainConfig {
        steps: args.parse_num("steps")?,
        lr: args.parse_num("lr")?,
        n_batches: args.parse_num("batches")?,
        log_every: 25,
    };
    let rep = train(&rt, &mut store, &ds, &cfg)?;
    checkpoint::save(args.get("out"), &store, None)?;
    println!("trained {} for {} steps: loss {:.4} -> {:.4} \
              ({:.1}s); saved to {}",
             meta.name, cfg.steps, rep.initial_loss, rep.final_loss,
             rep.seconds, args.get("out"));
    Ok(())
}

fn parse_refiner(s: &str, engine: &str) -> Result<Refiner, String> {
    match s {
        "none" => Ok(Refiner::None),
        "dsnot" => Ok(Refiner::Dsnot),
        "sparseswaps" => match engine {
            "native" => Ok(Refiner::SparseSwapsNative),
            e @ ("xla" | "pallas") =>
                Ok(Refiner::SparseSwapsOffload { impl_name: e.into() }),
            other => Err(format!("unknown engine {other:?}")),
        },
        other => Err(format!("unknown refiner {other:?}")),
    }
}

fn parse_criterion(s: &str) -> Result<Criterion, String> {
    Criterion::parse(s).ok_or_else(|| format!("bad criterion {s:?}"))
}

fn cmd_prune(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps prune", "run the pruning pipeline")
        .flag("config", "gpt-a", "model config name")
        .required_flag("checkpoint", "input checkpoint (.ssck)")
        .flag("criterion", "wanda", "warmstart: magnitude|wanda|ria")
        .flag("pattern", "0.6", "sparsity (0.6, 60%) or N:M (2:4)")
        .flag("refine", "sparseswaps", "refiner: none|dsnot|sparseswaps")
        .flag("engine", "xla", "sparseswaps engine: xla|pallas|native")
        .flag("tmax", "100", "max 1-swap iterations per row (T_max)")
        .flag("checkpoints", "", "comma-separated cumulative iteration \
                                  counts to snapshot (Table 3)")
        .flag("calib-batches", "8", "calibration batches")
        .bool_flag_on("layer-parallel", "refine independent row shards \
                                         of a block concurrently (thread \
                                         pool for native/dsnot, runtime \
                                         pool for offload)")
        .flag("shard-rows", "0", "rows per refinement shard work unit \
                                  (0 = adaptive: block rows / (4 x \
                                  workers)); masks are identical for \
                                  every value")
        .flag("seed", "42", "dataset seed")
        .bool_flag("oneshot", "single dense calibration pass \
                              (default: sequential per block)")
        .bool_flag("stream-weights", "stream weights per block from \
                                      the checkpoint instead of \
                                      loading the whole model \
                                      (out-of-core; masks are \
                                      bit-identical)")
        .flag("host-mem-budget", "0", "host memory budget for \
                                       streamed weights in MiB \
                                       (0 = unlimited)")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "runs/pruned.ssck", "output checkpoint (with masks)")
        .pool_flags("0")
        .journal_flags("reports/prune_journal");
    let args = spec.parse(argv)?;
    let pf = args.pool_flags()?;
    let jf = args.journal_flags()?;
    sparseswaps::util::kernels::select(&pf.kernels)?;
    let refiner = parse_refiner(args.get("refine"), args.get("engine"))?;
    let layer_parallel = args.get_bool("layer-parallel");
    let (devices, opts) = pool_opts(&pf);
    // Every refiner benefits from a multi-worker pool now: the
    // calibration passes fan batch stripes over all workers (the
    // striped decomposition keeps masks bit-identical at any device
    // count), and the offload engine additionally shards refinement
    // across them under --layer-parallel.
    let rt = start_pool(args.get("artifacts"), devices, opts, &jf)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let spec = MaskSpec {
        criterion: parse_criterion(args.get("criterion"))?,
        pattern_kind: PatternKind::parse(args.get("pattern"))?,
        refiner,
        t_max: args.parse_num("tmax")?,
        calib_batches: args.parse_num("calib-batches")?,
        sequential: !args.get_bool("oneshot"),
        checkpoints: args.parse_list("checkpoints")?,
    };
    let run = RunOptions {
        layer_parallel,
        shard_rows: args.parse_num("shard-rows")?,
        ..RunOptions::from_flags(&pf, &jf)
    };
    let t0 = std::time::Instant::now();
    let streaming = args.get_bool("stream-weights");
    let budget = args.parse_num::<usize>("host-mem-budget")?
        .saturating_mul(1 << 20);
    let (masks, rep, mem) = if streaming {
        let store = StreamingStore::open(args.get("checkpoint"), &meta,
                                         budget)?;
        let mut session = PruneSession::new(&rt, &store, &ds, run);
        let (masks, rep) = session.prune(&spec)?;
        checkpoint::save_streaming(args.get("out"), &store,
                                   Some(&masks))?;
        (masks, rep, store.stats())
    } else {
        let (store, _) = checkpoint::load(args.get("checkpoint"),
                                          &meta)?;
        let mut session = PruneSession::new(&rt, &store, &ds, run);
        let (masks, rep) = session.prune(&spec)?;
        checkpoint::save(args.get("out"), &store, Some(&masks))?;
        (masks, rep, store.stats())
    };
    println!("pruned {} [{} warmstart, {} refiner, {}, {} kernels]:",
             meta.name, spec.criterion.name(), spec.refiner.label(),
             spec.pattern_kind.label(),
             sparseswaps::util::kernels::active().name());
    println!("  layers: {}  sparsity: {:.2}%  total swaps: {}",
             rep.layers.len(), 100.0 * masks.overall_sparsity(),
             rep.layers.iter().map(|l| l.swaps).sum::<usize>());
    println!("  layer loss: {:.4} -> {:.4}  (mean rel. reduction {:.2}%)",
             rep.total_warmstart_loss(), rep.total_refined_loss(),
             100.0 * rep.mean_relative_reduction());
    println!("  time: {:.1}s (calib {:.1}s, refine {:.1}s); saved {}",
             t0.elapsed().as_secs_f64(), rep.calib_seconds,
             rep.refine_seconds, args.get("out"));
    let mib = |b: usize| b as f64 / (1u64 << 20) as f64;
    println!("  host memory [{}]: {:.1} MiB peak weights, {} tensor \
              loads ({:.1} MiB read from disk), {} block releases",
             if streaming { "streamed" } else { "resident" },
             mib(mem.peak_bytes), mem.loads, mib(mem.loaded_bytes),
             mem.releases);
    if !rep.snapshots.is_empty() {
        println!("  snapshots: {} checkpoint masks captured at {:?}",
                 rep.snapshots.len(),
                 rep.snapshots.keys().collect::<Vec<_>>());
    }
    let ct = &rep.calib_traffic;
    if ct.executions > 0 {
        println!("  calibration: {} exec(s), {:.1} MiB uploaded, \
                  {:.1} MiB downloaded, {}/{} probes resident \
                  ({:.0}%)",
                 ct.executions,
                 ct.upload_bytes as f64 / (1u64 << 20) as f64,
                 ct.download_bytes as f64 / (1u64 << 20) as f64,
                 ct.probe_hits, ct.probe_hits + ct.probe_misses,
                 100.0 * ct.probe_hit_rate());
    }
    print_pool_stats(&rt);
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new(
        "sparseswaps sweep",
        "ppl-vs-sparsity curves: calibrate once, walk a level x \
         criterion x refiner grid with warm-started mask continuation")
        .flag("config", "gpt-a", "model config name")
        .required_flag("checkpoint", "input checkpoint (.ssck)")
        .flag("grid", "0.3,0.5,0.6,0.7",
              "comma-separated levels: sparsities (0.5, 60%) and/or \
               N:M patterns (2:4)")
        .flag("criteria", "wanda", "comma-separated warmstart \
                                    criteria: magnitude|wanda|ria")
        .flag("refiners", "sparseswaps", "comma-separated refiners: \
                                          none|dsnot|sparseswaps")
        .flag("engine", "xla", "sparseswaps engine: xla|pallas|native")
        .flag("tmax", "25", "max 1-swap iterations per row (T_max)")
        .flag("calib-batches", "8", "calibration batches (one dense \
                                     pass shared by the whole grid)")
        .flag("val-batches", "4", "validation batches for per-point \
                                   perplexity (0 skips eval)")
        .bool_flag_on("warm-start", "warm-start each level from the \
                                     previous refined mask (=false \
                                     refines every point cold)")
        .bool_flag("cold-compare", "also refine each warm-started \
                                    point from a cold warmstart and \
                                    record the timing/loss delta")
        .flag("seed", "42", "dataset seed")
        .flag("out", "reports/sweep.json", "sweep curve artifact path")
        .flag("artifacts", "artifacts", "artifact directory")
        .pool_flags("0")
        .journal_flags("");
    let args = spec.parse(argv)?;
    let pf = args.pool_flags()?;
    let jf = args.journal_flags()?;
    sparseswaps::util::kernels::select(&pf.kernels)?;
    let mut levels = Vec::new();
    for tok in args.get("grid").split(',').filter(|s| !s.is_empty()) {
        levels.push(PatternKind::parse(tok.trim())?);
    }
    let mut criteria = Vec::new();
    for tok in args.get("criteria").split(',')
        .filter(|s| !s.is_empty()) {
        criteria.push(parse_criterion(tok.trim())?);
    }
    let mut refiners = Vec::new();
    for tok in args.get("refiners").split(',')
        .filter(|s| !s.is_empty()) {
        refiners.push(parse_refiner(tok.trim(), args.get("engine"))?);
    }
    // Calibration and per-point ppl eval fan over every pool worker
    // whatever the refiner grid, so the pool size is no longer gated
    // on an offload refiner being present.
    let (devices, opts) = pool_opts(&pf);
    let rt = start_pool(args.get("artifacts"), devices, opts, &jf)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let (store, _) = checkpoint::load(args.get("checkpoint"), &meta)?;
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let val_batches: usize = args.parse_num("val-batches")?;
    let cfg = SweepConfig {
        levels,
        criteria,
        refiners,
        t_max: args.parse_num("tmax")?,
        calib_batches: args.parse_num("calib-batches")?,
        warm_start: args.get_bool("warm-start"),
        cold_compare: args.get_bool("cold-compare"),
        eval_ppl: val_batches > 0,
        val_batches,
        out: Some(std::path::PathBuf::from(args.get("out"))),
    };
    // The journal flag block rides along for fault/quarantine knobs,
    // but sweeps themselves are never journaled (sweep() rejects it).
    let mut session = PruneSession::new(&rt, &store, &ds,
                                        RunOptions::from_flags(&pf,
                                                               &jf));
    let rep = sweep::sweep(&mut session, &cfg)?;
    let mut table = Table::new(
        &format!("sparsity sweep — {} ({} kernels)", meta.name,
                 sparseswaps::util::kernels::active().name()),
        &["point", "sparsity", "ppl", "refined loss", "swaps",
          "rows/s", "seconds", "warm"]);
    for p in &rep.points {
        table.row(vec![
            p.key.clone(),
            format!("{:.1}%", 100.0 * p.achieved_sparsity),
            match p.ppl {
                Some(v) => format!("{v:.3}"),
                None => "-".into(),
            },
            format!("{:.4}", p.refined_loss),
            p.swaps.to_string(),
            format!("{:.0}", p.rows_per_s),
            format!("{:.2}", p.seconds),
            if p.warm_from.is_some() { "warm".into() }
            else { "cold".into() },
        ]);
    }
    table.print();
    println!("swept {} point(s) in {:.1}s with {} calibration \
              pass(es); curve written to {}",
             rep.points.len(), rep.seconds, rep.calibrations,
             args.get("out"));
    print_pool_stats(&rt);
    Ok(())
}

fn cmd_eval(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps eval",
                            "perplexity + zero-shot of a checkpoint")
        .flag("config", "gpt-a", "model config name")
        .required_flag("checkpoint", "checkpoint (.ssck)")
        .flag("val-batches", "8", "validation batches")
        .flag("tasks", "64", "zero-shot tasks")
        .flag("seed", "42", "dataset seed")
        .bool_flag("dense", "ignore stored masks (evaluate dense)")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let (store, masks) = checkpoint::load(args.get("checkpoint"), &meta)?;
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let eval_store = match (&masks, args.get_bool("dense")) {
        (Some(m), false) => {
            println!("applying stored masks (sparsity {:.2}%)",
                     100.0 * m.overall_sparsity());
            store.masked(m)
        }
        _ => store.clone(),
    };
    let val = ds.batches(&meta, Split::Validation,
                         args.parse_num("val-batches")?);
    let ppl = perplexity(&rt, &eval_store, &val)?;
    let tasks = zeroshot::build_tasks(&ds, meta.vocab,
                                      args.parse_num("tasks")?, 911);
    let acc = zeroshot::accuracy(&rt, &eval_store, &tasks)?;
    println!("perplexity: {ppl:.3}");
    println!("zero-shot accuracy: {:.2}% ({} tasks, chance 25%)",
             100.0 * acc, tasks.len());
    Ok(())
}

fn cmd_report(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps report",
                            "regenerate a paper table/figure")
        .positional("experiment",
                    "table1|table2|table3|table4|table5|fig1|fig2|all",
                    true)
        .flag("model", "gpt-a", "model for single-model experiments")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "reports/report.md", "markdown output (appended)")
        .bool_flag("quick", "tiny model, reduced budgets")
        .pool_flags("1");
    let args = spec.parse(argv)?;
    let pf = args.pool_flags()?;
    sparseswaps::util::kernels::select(&pf.kernels)?;
    let (devices, opts) = pool_opts(&pf);
    let rt = RuntimePool::start(args.get("artifacts"), devices, opts)
        .map_err(|e| e.to_string())?;
    let quick = args.get_bool("quick")
        || std::env::var("SPARSESWAPS_QUICK").is_ok();
    let ctx = report::Ctx::new(rt, "runs", quick);
    let model = if quick { "tiny".to_string() }
                else { args.get("model").to_string() };
    let out = args.get("out");
    let exp = args.positional(0).unwrap().to_string();
    let run = |name: &str| -> CliResult {
        match name {
            "table1" => {
                let (a, b) = report::table1(&ctx)?;
                a.print();
                b.print();
                a.append_to(out)?;
                b.append_to(out)?;
            }
            "table2" => {
                let t = report::table2(&ctx)?;
                t.print();
                t.append_to(out)?;
            }
            "table3" => {
                let t = report::table3(&ctx, &model)?;
                t.print();
                t.append_to(out)?;
            }
            "table4" => {
                let t = report::table4(&ctx)?;
                t.print();
                t.append_to(out)?;
            }
            "table5" => {
                let t = report::table5(&ctx, &model)?;
                t.print();
                t.append_to(out)?;
            }
            "fig1" => {
                let (t, plot) = report::fig1(&ctx, &model)?;
                t.print();
                println!("{plot}");
                t.append_to(out)?;
            }
            "fig2" => {
                let (t, plot) = report::fig2(&ctx, &model)?;
                t.print();
                println!("{plot}");
                t.append_to(out)?;
            }
            other => return Err(
                format!("unknown experiment {other:?}").into()),
        }
        Ok(())
    };
    if exp == "all" {
        for name in ["table1", "table2", "table3", "table4", "table5",
                     "fig1", "fig2"] {
            println!("=== {name} ===");
            run(name)?;
        }
    } else {
        run(&exp)?;
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps inspect",
                            "list manifest configs and artifacts")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let m = rt.manifest();
    println!("configs:");
    for (name, cfg) in &m.configs {
        println!("  {name}: d_model={} n_heads={} d_ff={} blocks={} \
                  vocab={} seq={} batch={} ({} prunable layers, {} \
                  prunable weights)",
                 cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.n_blocks,
                 cfg.vocab, cfg.seq_len, cfg.batch, cfg.prunable.len(),
                 cfg.prunable_weight_count());
    }
    println!("artifacts: {}", m.artifacts.len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> =
        Default::default();
    for a in m.artifacts.values() {
        *by_kind.entry(a.kind.as_str()).or_default() += 1;
    }
    for (kind, count) in by_kind {
        println!("  {kind}: {count}");
    }
    Ok(())
}

fn cmd_analyze(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps analyze",
                            "calibration-statistics diagnostics \
                             (activation outliers, feature correlation)")
        .flag("config", "tiny", "model config name")
        .flag("checkpoint", "", "checkpoint (.ssck); fresh init if empty")
        .flag("calib-batches", "4", "calibration batches")
        .flag("seed", "42", "dataset seed")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let store = if args.get("checkpoint").is_empty() {
        ParamStore::init(&meta, meta.init_seed)
    } else {
        checkpoint::load(args.get("checkpoint"), &meta)?.0
    };
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let calib = ds.batches(&meta, Split::Calibration,
                           args.parse_num("calib-batches")?);
    let stats = sparseswaps::gram::accumulate(&rt, &store, &calib)?;
    println!("calibration: {} batches, {} tokens", stats.batches,
             stats.tokens);
    println!("{:<28} {}", "layer", "diagnostics");
    for layer in &meta.prunable {
        let g = stats.gram_for(layer);
        let d = sparseswaps::gram::analysis::diagnose(g);
        println!("{:<28} {}", layer.name, d.summary());
    }
    Ok(())
}
