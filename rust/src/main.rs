//! `sparseswaps` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   train    train a zoo model through the AOT train-step artifact
//!   prune    run the pruning pipeline (warmstart + refinement)
//!   eval     perplexity + zero-shot accuracy of a checkpoint
//!   report   regenerate a paper table/figure (table1..table5, fig1, fig2)
//!   inspect  list manifest artifacts and model configs

use std::process::ExitCode;

use sparseswaps::coordinator::{
    prune, train, PatternKind, PruneConfig, Refiner, TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, zeroshot};
use sparseswaps::model::{checkpoint, ParamStore};
use sparseswaps::pruning::Criterion;
use sparseswaps::report;
use sparseswaps::runtime::{Runtime, RuntimeOptions, RuntimePool};
use sparseswaps::util::cli::ArgSpec;
use sparseswaps::util::logging;

fn main() -> ExitCode {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprint!("{}", top_usage());
        return ExitCode::FAILURE;
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "prune" => cmd_prune(rest),
        "eval" => cmd_eval(rest),
        "report" => cmd_report(rest),
        "inspect" => cmd_inspect(rest),
        "analyze" => cmd_analyze(rest),
        "--help" | "-h" | "help" => {
            print!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}\n{}",
                             top_usage()).into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn top_usage() -> String {
    "sparseswaps — LLM pruning mask refinement (Zimmer et al., 2025)\n\n\
     USAGE:\n  sparseswaps <train|prune|eval|report|analyze|inspect> \
     [FLAGS]\n\n\
     Run `sparseswaps <cmd> --help` for per-command flags.\n".into()
}

fn runtime(args: &sparseswaps::util::cli::Args) -> Result<Runtime, String> {
    Runtime::start(args.get("artifacts")).map_err(|e| e.to_string())
}

/// Pool options from the shared `--devices` / `--device-mem-budget`
/// flags (0 devices = all cores; budget in MiB, 0 = unlimited).
fn pool_args(args: &sparseswaps::util::cli::Args)
    -> Result<(usize, RuntimeOptions), Box<dyn std::error::Error>> {
    let devices = match args.parse_num::<usize>("devices")? {
        0 => sparseswaps::util::threadpool::default_threads(),
        n => n,
    };
    let budget_mib: u64 = args.parse_num("device-mem-budget")?;
    let opts = RuntimeOptions {
        device_mem_budget: budget_mib.saturating_mul(1 << 20),
        ..RuntimeOptions::default()
    };
    Ok((devices, opts))
}

fn cmd_train(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps train",
                            "train a model via the AOT train-step")
        .flag("config", "gpt-a", "model config name from the manifest")
        .flag("steps", "300", "training steps")
        .flag("lr", "0.002", "Adam learning rate")
        .flag("batches", "24", "distinct training batches to cycle")
        .flag("seed", "42", "dataset seed")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "runs/model.ssck", "output checkpoint path");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let cfg = TrainConfig {
        steps: args.parse_num("steps")?,
        lr: args.parse_num("lr")?,
        n_batches: args.parse_num("batches")?,
        log_every: 25,
    };
    let rep = train(&rt, &mut store, &ds, &cfg)?;
    checkpoint::save(args.get("out"), &store, None)?;
    println!("trained {} for {} steps: loss {:.4} -> {:.4} \
              ({:.1}s); saved to {}",
             meta.name, cfg.steps, rep.initial_loss, rep.final_loss,
             rep.seconds, args.get("out"));
    Ok(())
}

fn parse_pattern(s: &str) -> Result<PatternKind, String> {
    if let Some(sparseswaps::pruning::Pattern::Nm { n, m }) =
        sparseswaps::pruning::Pattern::parse(s) {
        return Ok(PatternKind::Nm { n, m });
    }
    let v: f64 = s.trim_end_matches('%').parse()
        .map_err(|_| format!("bad pattern {s:?}: want e.g. 0.6 or 2:4"))?;
    let sparsity = if v > 1.0 { v / 100.0 } else { v };
    if !(0.0..1.0).contains(&sparsity) {
        return Err(format!("sparsity {sparsity} out of range"));
    }
    Ok(PatternKind::Unstructured { sparsity })
}

fn parse_refiner(s: &str, engine: &str) -> Result<Refiner, String> {
    match s {
        "none" => Ok(Refiner::None),
        "dsnot" => Ok(Refiner::Dsnot),
        "sparseswaps" => match engine {
            "native" => Ok(Refiner::SparseSwapsNative),
            e @ ("xla" | "pallas") =>
                Ok(Refiner::SparseSwapsOffload { impl_name: e.into() }),
            other => Err(format!("unknown engine {other:?}")),
        },
        other => Err(format!("unknown refiner {other:?}")),
    }
}

fn cmd_prune(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps prune", "run the pruning pipeline")
        .flag("config", "gpt-a", "model config name")
        .required_flag("checkpoint", "input checkpoint (.ssck)")
        .flag("criterion", "wanda", "warmstart: magnitude|wanda|ria")
        .flag("pattern", "0.6", "sparsity (0.6, 60%) or N:M (2:4)")
        .flag("refine", "sparseswaps", "refiner: none|dsnot|sparseswaps")
        .flag("engine", "xla", "sparseswaps engine: xla|pallas|native")
        .flag("tmax", "100", "max 1-swap iterations per row (T_max)")
        .flag("checkpoints", "", "comma-separated cumulative iteration \
                                  counts to snapshot (Table 3)")
        .flag("calib-batches", "8", "calibration batches")
        .flag("threads", "0", "worker threads (0 = all cores)")
        .flag("kernels", "auto", "kernel dispatch arm: auto|scalar|simd\
                                  |avx512 (scalar for cross-arm parity \
                                  testing)")
        .bool_flag_on("layer-parallel", "refine independent row shards \
                                         of a block concurrently (thread \
                                         pool for native/dsnot, runtime \
                                         pool for offload)")
        .flag("shard-rows", "0", "rows per refinement shard work unit \
                                  (0 = adaptive: block rows / (4 x \
                                  workers)); masks are identical for \
                                  every value")
        .flag("devices", "0", "offload runtime service workers \
                               (0 = all cores); >1 refines layers \
                               concurrently across devices")
        .flag("device-mem-budget", "512", "per-device buffer-cache \
                                           budget in MiB (0 = unlimited)")
        .flag("seed", "42", "dataset seed")
        .bool_flag("oneshot", "single dense calibration pass \
                              (default: sequential per block)")
        .flag("max-shard-retries", "2", "redispatches per shard for \
                                         transient worker failures")
        .flag("quarantine-after", "2", "consecutive shard failures \
                                        before a worker is \
                                        quarantined (0 = never)")
        .flag("journal", "reports/prune_journal",
              "mask journal directory for resumable runs (\"\" \
               disables journaling)")
        .bool_flag("resume", "resume from the journal: restore \
                              completed blocks and continue")
        .flag("fault-plan", "", "deterministic fault-injection spec \
                                 (e.g. \"seed=7;rate=0.05;kill=1\"); \
                                 also SPARSESWAPS_FAULTS")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "runs/pruned.ssck", "output checkpoint (with masks)");
    let args = spec.parse(argv)?;
    sparseswaps::util::kernels::select(args.get("kernels"))?;
    let refiner = parse_refiner(args.get("refine"), args.get("engine"))?;
    let layer_parallel = args.get_bool("layer-parallel");
    let (devices, opts) = pool_args(&args)?;
    // Only the offload engine with layer-parallel scheduling can use
    // more than one worker; everything else runs on the primary, so
    // don't spawn (and later compile on) idle service threads.
    let devices = match refiner {
        Refiner::SparseSwapsOffload { .. } if layer_parallel => devices,
        _ => 1,
    };
    let fault_plan = match args.get("fault-plan") {
        "" => sparseswaps::runtime::FaultPlan::from_env()?,
        spec => Some(sparseswaps::runtime::FaultPlan::parse(spec)?),
    };
    let rt = match fault_plan {
        Some(plan) => RuntimePool::start_with_faults(
            args.get("artifacts"), devices, opts, plan),
        None => RuntimePool::start(args.get("artifacts"), devices,
                                   opts),
    }
    .map_err(|e| e.to_string())?;
    rt.set_quarantine_after(args.parse_num("quarantine-after")?);
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let (store, _) = checkpoint::load(args.get("checkpoint"), &meta)?;
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let threads = match args.parse_num::<usize>("threads")? {
        0 => sparseswaps::util::threadpool::default_threads(),
        t => t,
    };
    let cfg = PruneConfig {
        criterion: Criterion::parse(args.get("criterion"))
            .ok_or_else(|| format!("bad criterion {:?}",
                                   args.get("criterion")))?,
        pattern_kind: parse_pattern(args.get("pattern"))?,
        refiner,
        t_max: args.parse_num("tmax")?,
        calib_batches: args.parse_num("calib-batches")?,
        sequential: !args.get_bool("oneshot"),
        checkpoints: args.parse_list("checkpoints")?,
        threads,
        layer_parallel,
        shard_rows: args.parse_num("shard-rows")?,
        max_shard_retries: args.parse_num("max-shard-retries")?,
        journal: match args.get("journal") {
            "" => None,
            dir => Some(std::path::PathBuf::from(dir)),
        },
        resume: args.get_bool("resume"),
        halt_after_block: None,
    };
    let t0 = std::time::Instant::now();
    let (masks, rep) = prune(&rt, &store, &ds, &cfg)?;
    checkpoint::save(args.get("out"), &store, Some(&masks))?;
    println!("pruned {} [{} warmstart, {} refiner, {}, {} kernels]:",
             meta.name, cfg.criterion.name(), cfg.refiner.label(),
             cfg.pattern_kind.label(),
             sparseswaps::util::kernels::active().name());
    println!("  layers: {}  sparsity: {:.2}%  total swaps: {}",
             rep.layers.len(), 100.0 * masks.overall_sparsity(),
             rep.layers.iter().map(|l| l.swaps).sum::<usize>());
    println!("  layer loss: {:.4} -> {:.4}  (mean rel. reduction {:.2}%)",
             rep.total_warmstart_loss(), rep.total_refined_loss(),
             100.0 * rep.mean_relative_reduction());
    println!("  time: {:.1}s (calib {:.1}s, refine {:.1}s); saved {}",
             t0.elapsed().as_secs_f64(), rep.calib_seconds,
             rep.refine_seconds, args.get("out"));
    if !rep.snapshots.is_empty() {
        println!("  snapshots: {} checkpoint masks captured at {:?}",
                 rep.snapshots.len(),
                 rep.snapshots.keys().collect::<Vec<_>>());
    }
    let ps = rt.stats_total();
    if ps.executions > 0 {
        println!("  runtime pool: {} device(s), {} artifact execs, \
                  buffer cache {}/{} hits ({:.0}%), {} evictions, \
                  {:.1} MiB summed per-device peaks, {} compiles \
                  ({} adopted from the shared cache)",
                 rt.devices(), ps.executions, ps.cache_hits,
                 ps.cache_hits + ps.cache_misses,
                 100.0 * ps.cache_hit_rate(), ps.cache_evictions,
                 ps.cache_peak_bytes as f64 / (1u64 << 20) as f64,
                 ps.compiles, ps.compiles_shared);
        println!("  key-only probes: {}/{} resident ({:.0}%), \
                  {:.1} MiB uploaded",
                 ps.probe_hits, ps.probe_hits + ps.probe_misses,
                 100.0 * ps.probe_hit_rate(),
                 ps.upload_bytes as f64 / (1u64 << 20) as f64);
    }
    if ps.shard_retries > 0 || ps.workers_quarantined > 0 {
        println!("  fault recovery: {} shard retries, {} worker(s) \
                  quarantined",
                 ps.shard_retries, ps.workers_quarantined);
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps eval",
                            "perplexity + zero-shot of a checkpoint")
        .flag("config", "gpt-a", "model config name")
        .required_flag("checkpoint", "checkpoint (.ssck)")
        .flag("val-batches", "8", "validation batches")
        .flag("tasks", "64", "zero-shot tasks")
        .flag("seed", "42", "dataset seed")
        .bool_flag("dense", "ignore stored masks (evaluate dense)")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let (store, masks) = checkpoint::load(args.get("checkpoint"), &meta)?;
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let eval_store = match (&masks, args.get_bool("dense")) {
        (Some(m), false) => {
            println!("applying stored masks (sparsity {:.2}%)",
                     100.0 * m.overall_sparsity());
            store.masked(m)
        }
        _ => store.clone(),
    };
    let val = ds.batches(&meta, Split::Validation,
                         args.parse_num("val-batches")?);
    let ppl = perplexity(&rt, &eval_store, &val)?;
    let tasks = zeroshot::build_tasks(&ds, meta.vocab,
                                      args.parse_num("tasks")?, 911);
    let acc = zeroshot::accuracy(&rt, &eval_store, &tasks)?;
    println!("perplexity: {ppl:.3}");
    println!("zero-shot accuracy: {:.2}% ({} tasks, chance 25%)",
             100.0 * acc, tasks.len());
    Ok(())
}

fn cmd_report(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps report",
                            "regenerate a paper table/figure")
        .positional("experiment",
                    "table1|table2|table3|table4|table5|fig1|fig2|all",
                    true)
        .flag("model", "gpt-a", "model for single-model experiments")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "reports/report.md", "markdown output (appended)")
        .flag("kernels", "auto",
              "kernel dispatch arm: auto|scalar|simd|avx512")
        .flag("devices", "1", "offload runtime service workers \
                               (0 = all cores)")
        .flag("device-mem-budget", "512", "per-device buffer-cache \
                                           budget in MiB (0 = unlimited)")
        .bool_flag("quick", "tiny model, reduced budgets");
    let args = spec.parse(argv)?;
    sparseswaps::util::kernels::select(args.get("kernels"))?;
    let (devices, opts) = pool_args(&args)?;
    let rt = RuntimePool::start(args.get("artifacts"), devices, opts)
        .map_err(|e| e.to_string())?;
    let quick = args.get_bool("quick")
        || std::env::var("SPARSESWAPS_QUICK").is_ok();
    let ctx = report::Ctx::new(rt, "runs", quick);
    let model = if quick { "tiny".to_string() }
                else { args.get("model").to_string() };
    let out = args.get("out");
    let exp = args.positional(0).unwrap().to_string();
    let run = |name: &str| -> CliResult {
        match name {
            "table1" => {
                let (a, b) = report::table1(&ctx)?;
                a.print();
                b.print();
                a.append_to(out)?;
                b.append_to(out)?;
            }
            "table2" => {
                let t = report::table2(&ctx)?;
                t.print();
                t.append_to(out)?;
            }
            "table3" => {
                let t = report::table3(&ctx, &model)?;
                t.print();
                t.append_to(out)?;
            }
            "table4" => {
                let t = report::table4(&ctx)?;
                t.print();
                t.append_to(out)?;
            }
            "table5" => {
                let t = report::table5(&ctx, &model)?;
                t.print();
                t.append_to(out)?;
            }
            "fig1" => {
                let (t, plot) = report::fig1(&ctx, &model)?;
                t.print();
                println!("{plot}");
                t.append_to(out)?;
            }
            "fig2" => {
                let (t, plot) = report::fig2(&ctx, &model)?;
                t.print();
                println!("{plot}");
                t.append_to(out)?;
            }
            other => return Err(
                format!("unknown experiment {other:?}").into()),
        }
        Ok(())
    };
    if exp == "all" {
        for name in ["table1", "table2", "table3", "table4", "table5",
                     "fig1", "fig2"] {
            println!("=== {name} ===");
            run(name)?;
        }
    } else {
        run(&exp)?;
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps inspect",
                            "list manifest configs and artifacts")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let m = rt.manifest();
    println!("configs:");
    for (name, cfg) in &m.configs {
        println!("  {name}: d_model={} n_heads={} d_ff={} blocks={} \
                  vocab={} seq={} batch={} ({} prunable layers, {} \
                  prunable weights)",
                 cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.n_blocks,
                 cfg.vocab, cfg.seq_len, cfg.batch, cfg.prunable.len(),
                 cfg.prunable_weight_count());
    }
    println!("artifacts: {}", m.artifacts.len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> =
        Default::default();
    for a in m.artifacts.values() {
        *by_kind.entry(a.kind.as_str()).or_default() += 1;
    }
    for (kind, count) in by_kind {
        println!("  {kind}: {count}");
    }
    Ok(())
}

fn cmd_analyze(argv: &[String]) -> CliResult {
    let spec = ArgSpec::new("sparseswaps analyze",
                            "calibration-statistics diagnostics \
                             (activation outliers, feature correlation)")
        .flag("config", "tiny", "model config name")
        .flag("checkpoint", "", "checkpoint (.ssck); fresh init if empty")
        .flag("calib-batches", "4", "calibration batches")
        .flag("seed", "42", "dataset seed")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = spec.parse(argv)?;
    let rt = runtime(&args)?;
    let meta = rt.manifest().config(args.get("config"))?.clone();
    let store = if args.get("checkpoint").is_empty() {
        ParamStore::init(&meta, meta.init_seed)
    } else {
        checkpoint::load(args.get("checkpoint"), &meta)?.0
    };
    let ds = Dataset::build(&meta, args.parse_num("seed")?);
    let calib = ds.batches(&meta, Split::Calibration,
                           args.parse_num("calib-batches")?);
    let stats = sparseswaps::gram::accumulate(&rt, &store, &calib)?;
    println!("calibration: {} batches, {} tokens", stats.batches,
             stats.tokens);
    println!("{:<28} {}", "layer", "diagnostics");
    for layer in &meta.prunable {
        let g = stats.gram_for(layer);
        let d = sparseswaps::gram::analysis::diagnose(g);
        println!("{:<28} {}", layer.name, d.summary());
    }
    Ok(())
}
