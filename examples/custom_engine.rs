//! Adding a refiner is a one-file change: implement `RefineEngine` and
//! (to use it in the pipeline) register a constructor in
//! `Refiner::shard_engine`.  This example implements a deliberately
//! simple surrogate refiner against the trait and compares it with the
//! exact SparseSwaps engine on a synthetic layer — no AOT artifacts
//! needed.  The contract's work unit is a *row shard* (`refine_rows`
//! over a row range); per-row refiners like this one implement it
//! directly and whole-layer callers get the provided `refine`.
//!
//!   cargo run --release --example custom_engine

use std::collections::BTreeMap;

use sparseswaps::pruning::engine::{
    LayerContext, RefineEngine, RefineError, RefineOutcome,
};
use sparseswaps::pruning::error::{layer_loss, row_loss};
use sparseswaps::pruning::mask::{mask_from_scores, validate, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    LayerOutcome, NativeEngine, RowOutcome,
};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

/// A greedy magnitude-pair refiner: per row, repeatedly swap the
/// smallest-|w| kept weight for the largest-|w| pruned weight whenever
/// that lowers the exact loss.  It ignores the Gram cross terms when
/// *choosing* the pair (unlike SparseSwaps' Eq.-5 argmin), so it
/// converges to worse optima — which is exactly what makes it a useful
/// trait demo: same contract, different algorithm.
struct GreedyMagnitudeSwap;

impl RefineEngine for GreedyMagnitudeSwap {
    fn name(&self) -> String {
        "greedy-magnitude".into()
    }

    fn refine_rows(&self, ctx: &LayerContext,
                   row_range: std::ops::Range<usize>, mask: &mut Matrix,
                   _checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        let (w, g) = (ctx.w, ctx.g);
        let mut rows = Vec::with_capacity(row_range.len());
        for (k, r) in row_range.enumerate() {
            let wr = w.row(r);
            let mut m = mask.row(k).to_vec();
            let loss_before = row_loss(wr, &m, g);
            let mut loss = loss_before;
            let mut swaps = 0;
            let mut converged = false;
            for _ in 0..ctx.t_max {
                let u = (0..wr.len())
                    .filter(|&i| m[i] > 0.5)
                    .min_by(|&a, &b| wr[a].abs().total_cmp(&wr[b].abs()));
                let p = (0..wr.len())
                    .filter(|&i| m[i] < 0.5)
                    .max_by(|&a, &b| wr[a].abs().total_cmp(&wr[b].abs()));
                let (Some(u), Some(p)) = (u, p) else {
                    converged = true;
                    break;
                };
                m[u] = 0.0;
                m[p] = 1.0;
                let trial = row_loss(wr, &m, g);
                if trial < loss {
                    loss = trial;
                    swaps += 1;
                } else {
                    // Revert and stop: the greedy pair no longer helps.
                    m[u] = 1.0;
                    m[p] = 0.0;
                    converged = true;
                    break;
                }
            }
            mask.row_mut(k).copy_from_slice(&m);
            rows.push(RowOutcome {
                loss_before,
                loss_after: loss,
                swaps,
                converged,
            });
        }
        Ok(RefineOutcome {
            layer: LayerOutcome { rows },
            snapshots: BTreeMap::new(),
        })
    }
}

fn main() {
    let (d_out, d_in, tokens) = (32, 64, 256);
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(tokens, d_in, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d_in, d_in);
    g.gram_accumulate(&x);
    let w = Matrix::from_fn(d_out, d_in, |_, _| rng.gaussian_f32());

    let pattern = Pattern::per_row_sparsity(d_in, 0.6);
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    let warm_loss = layer_loss(&w, &warm, &g);
    let ctx = LayerContext {
        w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 50,
        threads: 1, gmax: None,
    };

    println!("layer {d_out}x{d_in}, 60% per-row sparsity \
              (Wanda warmstart loss {warm_loss:.2})");
    let engines: Vec<Box<dyn RefineEngine>> = vec![
        Box::new(GreedyMagnitudeSwap),
        Box::new(NativeEngine::default()),
    ];
    let mut losses = Vec::new();
    for engine in &engines {
        let mut mask = warm.clone();
        let out = engine.refine(&ctx, &mut mask, &[]).unwrap();
        validate(&mask, pattern).unwrap();
        let loss = layer_loss(&w, &mask, &g);
        println!("  {:<20} loss {:>8.2}  ({} swaps, monotone: {})",
                 engine.name(), loss, out.layer.total_swaps(),
                 out.layer.total_after()
                 <= out.layer.total_before() + 1e-9);
        losses.push(loss);
    }
    // Both accept only loss-decreasing moves, so both refine.
    assert!(losses[0] <= warm_loss + 1e-9);
    assert!(losses[1] <= warm_loss + 1e-9);
    println!("custom engine plugged into the same trait \
              (greedy {:.2} vs sparseswaps {:.2})",
             losses[0], losses[1]);
}
