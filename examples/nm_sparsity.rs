//! Semi-structured sparsity demo: 2:4 and 4:8 patterns vs per-row
//! unstructured at matched 50% sparsity, with and without SparseSwaps.
//!
//!   make artifacts && cargo run --release --example nm_sparsity
//!   (SPARSESWAPS_E2E_CONFIG=tiny for a fast run)

use sparseswaps::coordinator::{
    train, MaskSpec, PatternKind, PruneSession, Refiner, RunOptions,
    TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::perplexity;
use sparseswaps::model::ParamStore;
use sparseswaps::runtime::{RuntimeOptions, RuntimePool};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    sparseswaps::util::logging::init_from_env();
    let config = std::env::var("SPARSESWAPS_E2E_CONFIG")
        .unwrap_or_else(|_| "tiny".into());
    let rt = RuntimePool::start("artifacts", 1,
                                RuntimeOptions::default())?;
    let meta = rt.manifest().config(&config)?.clone();
    let ds = Dataset::build(&meta, 42);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let steps = if config == "tiny" { 80 } else { 200 };
    train(&rt, &mut store, &ds,
          &TrainConfig { steps, lr: 2e-3, n_batches: 16, log_every: 50 })?;
    let val = ds.batches(&meta, Split::Validation, 4);
    let ppl_dense = perplexity(&rt, &store, &val)?;
    println!("dense ppl: {ppl_dense:.3}\n");
    println!("{:<14} {:>14} {:>14} {:>12}", "pattern", "wanda ppl",
             "+sparseswaps", "err. reduced");

    let mut session = PruneSession::new(&rt, &store, &ds,
                                        RunOptions::default());
    for pattern in [PatternKind::Unstructured { sparsity: 0.5 },
                    PatternKind::Nm { n: 2, m: 4 },
                    PatternKind::Nm { n: 4, m: 8 }] {
        let base = MaskSpec {
            pattern_kind: pattern,
            refiner: Refiner::None,
            t_max: 25,
            calib_batches: 4,
            sequential: true,
            ..Default::default()
        };
        let (masks_w, _) = session.prune(&base)?;
        let ppl_w = perplexity(&rt, &store.masked(&masks_w), &val)?;
        let spec = MaskSpec {
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            ..base
        };
        let (masks_s, rep) = session.prune(&spec)?;
        let ppl_s = perplexity(&rt, &store.masked(&masks_s), &val)?;
        println!("{:<14} {:>14.3} {:>14.3} {:>11.1}%",
                 pattern.label(), ppl_w, ppl_s,
                 100.0 * rep.mean_relative_reduction());
        // N:M swaps stay within blocks; per-row dominates N:M in
        // achievable loss because its swap space is a superset.
        assert!(rep.mean_relative_reduction() >= 0.0);
    }
    Ok(())
}
