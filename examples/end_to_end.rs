//! End-to-end validation driver (EXPERIMENTS.md records a full run):
//! train a small LLaMA-style GPT from scratch through the AOT
//! train-step artifact, prune it with Wanda, refine the masks with
//! SparseSwaps, and report perplexity + zero-shot accuracy for the
//! dense / Wanda / refined models.
//!
//!   make artifacts && cargo run --release --example end_to_end
//!   (SPARSESWAPS_E2E_CONFIG=tiny for a fast run)

use sparseswaps::coordinator::{
    train, MaskSpec, PatternKind, PruneSession, Refiner, RunOptions,
    TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, zeroshot};
use sparseswaps::model::ParamStore;
use sparseswaps::runtime::{RuntimeOptions, RuntimePool};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    sparseswaps::util::logging::init_from_env();
    let config = std::env::var("SPARSESWAPS_E2E_CONFIG")
        .unwrap_or_else(|_| "gpt-a".into());
    let steps: usize = std::env::var("SPARSESWAPS_E2E_STEPS")
        .ok().and_then(|s| s.parse().ok())
        .unwrap_or(if config == "tiny" { 80 } else { 300 });

    // SPARSESWAPS_DEVICES>1 fans offload refinement out across pool
    // workers (masks are bit-identical to the serial schedule).
    let devices = std::env::var("SPARSESWAPS_DEVICES")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let rt = RuntimePool::start("artifacts", devices,
                                RuntimeOptions::default())?;
    let meta = rt.manifest().config(&config)?.clone();
    println!("== end-to-end: {} (d_model={}, {} blocks, {} prunable \
              weights) ==",
             meta.name, meta.d_model, meta.n_blocks,
             meta.prunable_weight_count());

    // 1. Data + training.
    let ds = Dataset::build(&meta, 42);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let tcfg = TrainConfig { steps, lr: 2e-3, n_batches: 24,
                             log_every: 25 };
    let trep = train(&rt, &mut store, &ds, &tcfg)?;
    println!("trained {steps} steps in {:.1}s; loss {:.3} -> {:.3}",
             trep.seconds, trep.initial_loss, trep.final_loss);
    println!("loss curve: {:?}",
             trep.loss_curve.iter()
                 .map(|(s, l)| format!("{s}:{l:.2}"))
                 .collect::<Vec<_>>());

    // 2. Evaluate dense.
    let val = ds.batches(&meta, Split::Validation, 6);
    let tasks = zeroshot::build_tasks(&ds, meta.vocab, 64, 911);
    let ppl_dense = perplexity(&rt, &store, &val)?;
    let acc_dense = zeroshot::accuracy(&rt, &store, &tasks)?;

    // 3. Prune: Wanda warmstart at 60%, then SparseSwaps refinement.
    // Both specs run through one session over (pool, store, dataset).
    let mut session = PruneSession::new(&rt, &store, &ds,
                                        RunOptions::default());
    let base = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::None,
        t_max: 50,
        calib_batches: 4,
        sequential: true,
        ..Default::default()
    };
    let (masks_w, _) = session.prune(&base)?;
    let wanda_store = store.masked(&masks_w);
    let ppl_w = perplexity(&rt, &wanda_store, &val)?;
    let acc_w = zeroshot::accuracy(&rt, &wanda_store, &tasks)?;

    let spec_ss = MaskSpec {
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        ..base
    };
    let t0 = std::time::Instant::now();
    let (masks_s, rep) = session.prune(&spec_ss)?;
    let prune_secs = t0.elapsed().as_secs_f64();
    let ss_store = store.masked(&masks_s);
    let ppl_s = perplexity(&rt, &ss_store, &val)?;
    let acc_s = zeroshot::accuracy(&rt, &ss_store, &tasks)?;

    // 4. Report.
    println!("\n{:<22} {:>10} {:>10}", "model", "ppl", "0-shot");
    println!("{:<22} {:>10.3} {:>9.1}%", "dense", ppl_dense,
             100.0 * acc_dense);
    println!("{:<22} {:>10.3} {:>9.1}%", "wanda 60%", ppl_w,
             100.0 * acc_w);
    println!("{:<22} {:>10.3} {:>9.1}%", "wanda+sparseswaps", ppl_s,
             100.0 * acc_s);
    println!("\nSparseSwaps: mean per-layer error reduction {:.1}% \
              ({} swaps across {} layers, {:.1}s total)",
             100.0 * rep.mean_relative_reduction(),
             rep.layers.iter().map(|l| l.swaps).sum::<usize>(),
             rep.layers.len(), prune_secs);
    // Paper shape: refinement must cut local error everywhere...
    assert!(rep.mean_relative_reduction() > 0.1);
    for l in &rep.layers {
        assert!(l.loss_refined <= l.loss_warmstart * 1.0001 + 1e-9);
    }
    // ...and at 60% sparsity it should not be worse than Wanda by more
    // than noise (it usually improves ppl).
    assert!(ppl_s <= ppl_w * 1.10,
            "refined ppl {ppl_s} much worse than wanda {ppl_w}");
    println!("\nOK");
    Ok(())
}
