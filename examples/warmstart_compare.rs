//! Warmstart robustness demo (paper Table 4): magnitude / Wanda / RIA
//! warmstarts, each refined by DSnoT and SparseSwaps.  Shows that weaker
//! warmstarts see larger relative reductions and that SparseSwaps is
//! warmstart-agnostic.
//!
//!   make artifacts && cargo run --release --example warmstart_compare

use sparseswaps::coordinator::{
    train, MaskSpec, PatternKind, PruneSession, Refiner, RunOptions,
    TrainConfig,
};
use sparseswaps::data::Dataset;
use sparseswaps::model::ParamStore;
use sparseswaps::pruning::Criterion;
use sparseswaps::runtime::{RuntimeOptions, RuntimePool};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    sparseswaps::util::logging::init_from_env();
    let config = std::env::var("SPARSESWAPS_E2E_CONFIG")
        .unwrap_or_else(|_| "tiny".into());
    let rt = RuntimePool::start("artifacts", 1,
                                RuntimeOptions::default())?;
    let meta = rt.manifest().config(&config)?.clone();
    let ds = Dataset::build(&meta, 42);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let steps = if config == "tiny" { 80 } else { 200 };
    train(&rt, &mut store, &ds,
          &TrainConfig { steps, lr: 2e-3, n_batches: 16, log_every: 50 })?;

    println!("{:<12} {:>16} {:>16} {:>16}", "warmstart",
             "warmstart loss", "dsnot loss", "sparseswaps loss");
    let mut reductions = Vec::new();
    // One session: all nine one-shot runs share a single dense
    // calibration pass instead of recomputing the Grams per run.
    let mut session = PruneSession::new(&rt, &store, &ds,
                                        RunOptions::default());
    for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::Ria] {
        let base = MaskSpec {
            criterion: crit,
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: Refiner::None,
            t_max: 25,
            calib_batches: 4,
            sequential: false,
            ..Default::default()
        };
        let (_, rep_warm) = session.prune(&base)?;
        let (_, rep_dsnot) = session.prune(&MaskSpec {
            refiner: Refiner::Dsnot, ..base.clone()
        })?;
        let (_, rep_ss) = session.prune(&MaskSpec {
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            ..base
        })?;
        println!("{:<12} {:>16.1} {:>16.1} {:>16.1}   (SS -{:.1}%)",
                 crit.name(),
                 rep_warm.total_refined_loss(),
                 rep_dsnot.total_refined_loss(),
                 rep_ss.total_refined_loss(),
                 100.0 * rep_ss.mean_relative_reduction());
        // SparseSwaps is monotone: never worse than its warmstart.
        assert!(rep_ss.total_refined_loss()
                <= rep_warm.total_refined_loss() * 1.0001);
        reductions.push((crit, rep_ss.mean_relative_reduction()));
    }
    // Table 4 shape: magnitude (weakest warmstart) gains at least as
    // much relative reduction as wanda.
    let get = |c: Criterion| reductions.iter()
        .find(|(cc, _)| *cc == c).unwrap().1;
    assert!(get(Criterion::Magnitude) >= get(Criterion::Wanda) * 0.7);
    println!("\nOK — weaker warmstarts leave more room, SparseSwaps \
              refines all of them monotonically");
    Ok(())
}
