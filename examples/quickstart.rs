//! Quickstart: refine a single layer's pruning mask with SparseSwaps.
//!
//! Uses the native (pure-Rust) incremental active-set engine on
//! synthetic calibration data, so it runs without AOT artifacts.
//! Demonstrates the core objects: Gram matrix, Wanda warmstart,
//! Algorithm 1, the exact per-row loss, and the `RefineEngine` trait
//! with Table-3 style iteration checkpoints.
//!
//!   cargo run --release --example quickstart
//!
//! For the offload path, `sparseswaps prune` takes `--devices N`
//! (runtime-pool workers; layers of a block refine concurrently,
//! masks bit-identical to `--devices 1`) and `--device-mem-budget`
//! MiB (per-device resident buffer cache; see the README's "Runtime
//! pool & device-buffer cache" section).

use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::error::layer_loss;
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    refine_layer, NativeEngine, SwapConfig,
};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    let (d_out, d_in, tokens) = (64, 128, 512);
    let mut rng = Rng::new(0);

    // Correlated synthetic calibration activations: X = B (I + 0.9 M).
    let base = Matrix::from_fn(tokens, d_in, |_, _| rng.gaussian_f32());
    let mix = Matrix::from_fn(d_in, d_in, |_, _| {
        rng.gaussian_f32() / (d_in as f32).sqrt()
    });
    let mut mixer = Matrix::eye(d_in);
    for i in 0..d_in {
        for j in 0..d_in {
            mixer.set(i, j, mixer.at(i, j) + 0.9 * mix.at(i, j));
        }
    }
    // Row-panel-parallel matmul (bit-identical to the single-thread
    // path for any thread count).
    let x = base.matmul_par(&mixer, 4);

    // The Gram matrix G = X^T X is all the algorithm ever needs
    // (paper Sec 2.1.2) — accumulate it streaming, O(d_in^2) memory.
    let mut g = Matrix::zeros(d_in, d_in);
    g.gram_accumulate(&x);

    let w = Matrix::from_fn(d_out, d_in, |_, _| rng.gaussian_f32());

    // Wanda warmstart at 60% per-row sparsity: |W_ij| * sqrt(G_jj).
    let pattern = Pattern::per_row_sparsity(d_in, 0.6);
    let scores = saliency::wanda(&w, &g.diag());
    let warm_mask = mask_from_scores(&scores, pattern);
    let warmstart_loss = layer_loss(&w, &warm_mask, &g);

    // SparseSwaps: exact 1-swap refinement (Algorithm 1).
    let cfg = SwapConfig { t_max: 100, eps: 0.0 };
    let mut mask = warm_mask.clone();
    let outcome = refine_layer(&w, &mut mask, &g, pattern, &cfg, 4);
    let refined_loss = layer_loss(&w, &mask, &g);

    println!("layer {d_out}x{d_in}, 60% per-row sparsity");
    println!("  Wanda warmstart loss : {warmstart_loss:.2}");
    println!("  after SparseSwaps    : {refined_loss:.2}");
    println!("  relative reduction   : {:.1}%  ({} swaps, {} rows \
              converged)",
             100.0 * (1.0 - refined_loss / warmstart_loss),
             outcome.total_swaps(),
             outcome.rows.iter().filter(|r| r.converged).count());
    assert!(refined_loss < warmstart_loss);

    // Same engine through the uniform RefineEngine trait, capturing
    // mask snapshots after 1, 5 and 25 swaps/row (paper Table 3).
    let ctx = LayerContext {
        w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 100,
        threads: 4, gmax: None,
    };
    let mut mask2 = warm_mask.clone();
    let out = NativeEngine::default()
        .refine(&ctx, &mut mask2, &[1, 5, 25])
        .expect("native engine is infallible");
    println!("  loss trajectory (swaps/row -> loss):");
    for (cp, snap) in &out.snapshots {
        println!("    {cp:>3} -> {:.2}", layer_loss(&w, snap, &g));
    }
    // The trait path and the direct call are the same engine.
    assert_eq!(mask2.data, mask.data);
}
